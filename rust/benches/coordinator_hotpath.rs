//! L3 hot-path microbenchmarks (§Perf): where does coordinator time go?
//!
//! Decomposes one train step into: batch generation, tensor->literal
//! upload, execute, download.  The §Perf target is coordinator overhead
//! (everything but execute) < 5% of step time, and the cost of the obs
//! layer with tracing *disabled* ≤ 2% (a disabled span is one relaxed
//! atomic load — measured below, not assumed).
//!
//! The batch-generation, span-overhead, and kernel-subsystem sections run
//! offline; the engine-backed sections need `--features pjrt` plus built
//! artifacts.  The kernel section compares the naive scalar oracles
//! against the tiled kernels at 1 thread and at the pool width, and the
//! series land in a `BENCH_hotpath.json` artifact (override with `--out`).

use std::time::Duration;

use skyformer::data::batch::{Dataset, Split};
use skyformer::kernels::{self, ops::reference, pool, KernelCtx};
use skyformer::linalg::solve;
use skyformer::linalg::Matrix;
use skyformer::obs;
use skyformer::runtime::manifest::TaskConfig;
use skyformer::util::args::Args;
use skyformer::util::bench::bench;
use skyformer::util::json::{self, Value};
use skyformer::util::rng::Rng;

fn listops_task() -> TaskConfig {
    TaskConfig {
        name: "listops".into(),
        seq_len: 512,
        vocab_size: 20,
        num_classes: 10,
        batch_size: 8,
        dual: false,
    }
}

fn main() {
    // 1. batch generation (native path — includes one disabled span/batch)
    obs::set_enabled(false);
    let ds = Dataset::for_task(&listops_task(), 0).unwrap();
    let mut i = 0u64;
    let s_off = bench("data: batch generation (tracing off)", Duration::from_secs(2), || {
        let b = ds.batch(Split::Train, i);
        std::hint::black_box(b);
        i += 1;
    });
    println!("{s_off}");

    // 2. the same loop with tracing ON (spans recorded per batch)
    obs::set_enabled(true);
    let mut j = 0u64;
    let s_on = bench("data: batch generation (tracing on)", Duration::from_secs(2), || {
        let b = ds.batch(Split::Train, j);
        std::hint::black_box(b);
        j += 1;
    });
    println!("{s_on}");
    obs::set_enabled(false);
    let recorded = obs::span::drain_events().len();

    // 3. disabled-span cost in isolation: 1000 spans per iteration
    let s_span = bench("obs: 1000 disabled spans", Duration::from_millis(500), || {
        for _ in 0..1000 {
            let g = obs::span("bench", "noop");
            std::hint::black_box(&g);
        }
    });
    println!("{s_span}");

    let per_span_ns = s_span.mean.as_secs_f64() * 1e9 / 1000.0;
    let disabled_pct = per_span_ns / (s_off.mean.as_secs_f64() * 1e9) * 100.0;
    let enabled_pct =
        (s_on.mean.as_secs_f64() / s_off.mean.as_secs_f64() - 1.0) * 100.0;
    println!(
        "\nobs overhead: disabled span {per_span_ns:.1}ns => {disabled_pct:.3}% of a batch \
         (target <= 2%); tracing enabled costs {enabled_pct:+.2}% ({recorded} events recorded)"
    );

    let mut kernel_rows = kernel_sections();
    kernel_rows.extend(pool_sections());
    let artifact = json::obj(vec![
        ("bench", json::s("coordinator_hotpath")),
        ("kernel_rows", Value::Array(kernel_rows)),
        ("metrics", obs::snapshot().to_json()),
    ]);
    let args = Args::from_env();
    let out_path = args.get_or("out", "BENCH_hotpath.json").to_string();
    match std::fs::write(&out_path, json::to_string(&artifact)) {
        Ok(()) => println!("bench artifact written to {out_path}"),
        Err(e) => eprintln!("coordinator_hotpath: cannot write {out_path}: {e}"),
    }

    engine_sections();
}

/// Scalar oracle vs tiled kernel (1 thread, then the pool width) on the
/// attention-sized shapes the coordinator hot path actually runs.  The
/// 1-thread series isolates tiling+fusion gains; the N-thread series adds
/// the pool (on a single-core host the two coincide — the speedup column
/// makes that visible instead of assuming it).
fn kernel_sections() -> Vec<Value> {
    let n = 256usize;
    let p = 32usize;
    let pool = KernelCtx::global().threads;
    let mut rng = Rng::new(42);
    let a = Matrix::randn(&mut rng, n, n, 0.5);
    let b = Matrix::randn(&mut rng, n, n, 0.5);
    let q = Matrix::randn(&mut rng, n, p, 0.5);
    let k = Matrix::randn(&mut rng, n, p, 0.5);
    let v = Matrix::randn(&mut rng, n, p, 1.0);
    let s = kernels::matmul_transb(KernelCtx::with_threads(1), &q, &k);
    let budget = Duration::from_millis(700);

    println!("\nkernel subsystem: scalar oracle vs tiled kernel, n={n} p={p} pool={pool}");
    let mut rows = Vec::new();

    fn section(
        rows: &mut Vec<Value>,
        budget: Duration,
        pool: usize,
        kernel: &str,
        scalar: &mut dyn FnMut(),
        kernel_1t: &mut dyn FnMut(),
        kernel_nt: &mut dyn FnMut(),
    ) {
        let s_scalar = bench(&format!("{kernel}: scalar reference"), budget, scalar);
        println!("{s_scalar}");
        let s_1t = bench(&format!("{kernel}: kernel 1 thread"), budget, kernel_1t);
        println!("{s_1t}");
        let s_nt = bench(&format!("{kernel}: kernel {pool} threads"), budget, kernel_nt);
        println!("{s_nt}");
        println!(
            "  {kernel}: kernel/scalar speedup {:.2}x (1t), {:.2}x ({pool}t)",
            s_scalar.mean.as_secs_f64() / s_1t.mean.as_secs_f64().max(1e-12),
            s_scalar.mean.as_secs_f64() / s_nt.mean.as_secs_f64().max(1e-12),
        );
        for (series, stats) in [("scalar", s_scalar), ("kernel_1t", s_1t), ("kernel_nt", s_nt)] {
            let threads = if series == "kernel_nt" { pool } else { 1 };
            let mut row = stats.to_json();
            if let Value::Object(map) = &mut row {
                map.insert("kernel".into(), json::s(kernel));
                map.insert("series".into(), json::s(series));
                map.insert("threads".into(), json::num(threads as f64));
            }
            rows.push(row);
        }
    }

    let ctx1 = KernelCtx::with_threads(1);
    let ctxn = KernelCtx::with_threads(pool);
    section(
        &mut rows,
        budget,
        pool,
        "matmul",
        &mut || {
            std::hint::black_box(reference::matmul(&a, &b));
        },
        &mut || {
            std::hint::black_box(kernels::matmul(ctx1, &a, &b));
        },
        &mut || {
            std::hint::black_box(kernels::matmul(ctxn, &a, &b));
        },
    );
    section(
        &mut rows,
        budget,
        pool,
        "gaussian_scores",
        &mut || {
            std::hint::black_box(reference::gaussian_scores(&q, &k));
        },
        &mut || {
            std::hint::black_box(kernels::gaussian_scores(ctx1, &q, &k));
        },
        &mut || {
            std::hint::black_box(kernels::gaussian_scores(ctxn, &q, &k));
        },
    );
    section(
        &mut rows,
        budget,
        pool,
        "row_softmax_matmul",
        &mut || {
            std::hint::black_box(reference::row_softmax_matmul(&s, &v));
        },
        &mut || {
            std::hint::black_box(kernels::row_softmax_matmul(ctx1, &s, &v));
        },
        &mut || {
            std::hint::black_box(kernels::row_softmax_matmul(ctxn, &s, &v));
        },
    );
    rows
}

/// Scoped vs pinned pool backend on the two workloads the pool refactor
/// targets: one large matmul (per-call spawn cost amortised — pinned must
/// be no slower) and a Newton–Schulz iteration at d=128, a series of many
/// small back-to-back matmuls where per-call thread spawning dominates
/// the scoped backend (pinned should win).  On a single-core host both
/// modes inline and the series coincide — the printed ratio makes that
/// visible instead of assuming it.
fn pool_sections() -> Vec<Value> {
    let pool_width = KernelCtx::global().threads;
    let budget = Duration::from_millis(700);
    let mut rng = Rng::new(7);
    let a = Matrix::randn(&mut rng, 256, 256, 0.5);
    let b = Matrix::randn(&mut rng, 256, 256, 0.5);
    // A 128x128 Gaussian kernel gram: positive definite, so ns_inverse
    // converges, and each internal matmul (2*128^3 flops) just clears the
    // parallel threshold — the pool engages on every small step.
    let x = Matrix::randn(&mut rng, 128, 32, 0.3);
    let gram = kernels::gaussian_scores(KernelCtx::with_threads(1), &x, &x);

    println!("\npool backend: scoped vs pinned, width={pool_width}");
    let mut rows = Vec::new();
    let saved = pool::current_mode();
    for mode in [pool::Mode::Scoped, pool::Mode::Pinned] {
        let ctx = KernelCtx::with_threads(pool_width).with_mode(mode);
        let s_mm = bench(&format!("pool_matmul_256: {} backend", mode.name()), budget, || {
            std::hint::black_box(kernels::matmul(ctx, &a, &b));
        });
        println!("{s_mm}");
        // ns_inverse reads KernelCtx::global() internally; steer it via
        // the process-wide mode override and restore below.
        pool::set_mode(mode);
        let s_ns = bench(&format!("pool_ns_series_128: {} backend", mode.name()), budget, || {
            std::hint::black_box(solve::ns_inverse(&gram, 1e-3, 8));
        });
        println!("{s_ns}");
        for (kernel, stats) in [("pool_matmul_256", s_mm), ("pool_ns_series_128", s_ns)] {
            let mut row = stats.to_json();
            if let Value::Object(map) = &mut row {
                map.insert("kernel".into(), json::s(kernel));
                map.insert("series".into(), json::s(mode.name()));
                map.insert("threads".into(), json::num(pool_width as f64));
            }
            rows.push(row);
        }
    }
    pool::set_mode(saved);
    rows
}

#[cfg(not(feature = "pjrt"))]
fn engine_sections() {
    eprintln!("coordinator_hotpath: engine sections skipped (build with --features pjrt)");
}

#[cfg(feature = "pjrt")]
fn engine_sections() {
    use skyformer::coordinator::trainer::{TrainConfig, Trainer};
    use skyformer::runtime::engine::Engine;
    use skyformer::runtime::tensor::Tensor;

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let engine = match Engine::new(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("coordinator_hotpath: engine sections skipped ({e})");
            return;
        }
    };
    let Ok(spec) = engine
        .manifest()
        .find("listops", "skyformer", "train", false)
        .cloned()
    else {
        eprintln!("coordinator_hotpath: listops_skyformer not built");
        return;
    };
    let ds = Dataset::for_task(&spec.task_config, 0).unwrap();

    // host->literal conversion for one full input set
    let init = engine.load("listops", "skyformer", "init", false).unwrap();
    let state = init.run(&[Tensor::scalar_u32(0)]).unwrap();
    let batch = ds.batch(Split::Train, 0);
    let s = bench("runtime: tensors -> literals", Duration::from_secs(2), || {
        for t in &state {
            std::hint::black_box(t.to_literal().unwrap());
        }
        std::hint::black_box(batch.tokens.to_literal().unwrap());
    });
    println!("{s}");

    // full step through the Trainer (execute dominates)
    let cfg = TrainConfig::new("listops", "skyformer");
    let mut trainer = Trainer::new(&engine, cfg).unwrap();
    let _ = trainer.step(0);
    let mut step = 1usize;
    let s_all = bench("trainer: full step", Duration::from_secs(8), || {
        trainer.step(step).unwrap();
        step += 1;
    });
    println!("{s_all}");

    // exec-only accounting from the executable's internal stats
    let exec = engine.load("listops", "skyformer", "train", false).unwrap();
    let st = exec.stats.borrow();
    if st.calls > 0 {
        let exec_ms = st.exec_seconds / st.calls as f64 * 1e3;
        let upload_ms = st.upload_seconds / st.calls as f64 * 1e3;
        let download_ms = st.download_seconds / st.calls as f64 * 1e3;
        let total = s_all.mean_ms();
        println!(
            "\nper-step decomposition: execute {exec_ms:.1}ms, upload {upload_ms:.1}ms, \
             download {download_ms:.1}ms, other {:.1}ms",
            (total - exec_ms - upload_ms - download_ms).max(0.0)
        );
        println!(
            "coordinator overhead: {:.1}% of step (target < 5%)",
            100.0 * (total - exec_ms) / total
        );
    }
}
