//! L3 hot-path microbenchmarks (§Perf): where does coordinator time go?
//!
//! Decomposes one train step into: batch generation, tensor->literal
//! upload, execute, download.  The §Perf target is coordinator overhead
//! (everything but execute) < 5% of step time, and the cost of the obs
//! layer with tracing *disabled* ≤ 2% (a disabled span is one relaxed
//! atomic load — measured below, not assumed).
//!
//! The batch-generation and span-overhead sections run offline; the
//! engine-backed sections need `--features pjrt` plus built artifacts.

use std::time::Duration;

use skyformer::data::batch::{Dataset, Split};
use skyformer::obs;
use skyformer::runtime::manifest::TaskConfig;
use skyformer::util::bench::bench;

fn listops_task() -> TaskConfig {
    TaskConfig {
        name: "listops".into(),
        seq_len: 512,
        vocab_size: 20,
        num_classes: 10,
        batch_size: 8,
        dual: false,
    }
}

fn main() {
    // 1. batch generation (native path — includes one disabled span/batch)
    obs::set_enabled(false);
    let ds = Dataset::for_task(&listops_task(), 0).unwrap();
    let mut i = 0u64;
    let s_off = bench("data: batch generation (tracing off)", Duration::from_secs(2), || {
        let b = ds.batch(Split::Train, i);
        std::hint::black_box(b);
        i += 1;
    });
    println!("{s_off}");

    // 2. the same loop with tracing ON (spans recorded per batch)
    obs::set_enabled(true);
    let mut j = 0u64;
    let s_on = bench("data: batch generation (tracing on)", Duration::from_secs(2), || {
        let b = ds.batch(Split::Train, j);
        std::hint::black_box(b);
        j += 1;
    });
    println!("{s_on}");
    obs::set_enabled(false);
    let recorded = obs::span::drain_events().len();

    // 3. disabled-span cost in isolation: 1000 spans per iteration
    let s_span = bench("obs: 1000 disabled spans", Duration::from_millis(500), || {
        for _ in 0..1000 {
            let g = obs::span("bench", "noop");
            std::hint::black_box(&g);
        }
    });
    println!("{s_span}");

    let per_span_ns = s_span.mean.as_secs_f64() * 1e9 / 1000.0;
    let disabled_pct = per_span_ns / (s_off.mean.as_secs_f64() * 1e9) * 100.0;
    let enabled_pct =
        (s_on.mean.as_secs_f64() / s_off.mean.as_secs_f64() - 1.0) * 100.0;
    println!(
        "\nobs overhead: disabled span {per_span_ns:.1}ns => {disabled_pct:.3}% of a batch \
         (target <= 2%); tracing enabled costs {enabled_pct:+.2}% ({recorded} events recorded)"
    );

    engine_sections();
}

#[cfg(not(feature = "pjrt"))]
fn engine_sections() {
    eprintln!("coordinator_hotpath: engine sections skipped (build with --features pjrt)");
}

#[cfg(feature = "pjrt")]
fn engine_sections() {
    use skyformer::coordinator::trainer::{TrainConfig, Trainer};
    use skyformer::runtime::engine::Engine;
    use skyformer::runtime::tensor::Tensor;

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let engine = match Engine::new(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("coordinator_hotpath: engine sections skipped ({e})");
            return;
        }
    };
    let Ok(spec) = engine
        .manifest()
        .find("listops", "skyformer", "train", false)
        .cloned()
    else {
        eprintln!("coordinator_hotpath: listops_skyformer not built");
        return;
    };
    let ds = Dataset::for_task(&spec.task_config, 0).unwrap();

    // host->literal conversion for one full input set
    let init = engine.load("listops", "skyformer", "init", false).unwrap();
    let state = init.run(&[Tensor::scalar_u32(0)]).unwrap();
    let batch = ds.batch(Split::Train, 0);
    let s = bench("runtime: tensors -> literals", Duration::from_secs(2), || {
        for t in &state {
            std::hint::black_box(t.to_literal().unwrap());
        }
        std::hint::black_box(batch.tokens.to_literal().unwrap());
    });
    println!("{s}");

    // full step through the Trainer (execute dominates)
    let cfg = TrainConfig::new("listops", "skyformer");
    let mut trainer = Trainer::new(&engine, cfg).unwrap();
    let _ = trainer.step(0);
    let mut step = 1usize;
    let s_all = bench("trainer: full step", Duration::from_secs(8), || {
        trainer.step(step).unwrap();
        step += 1;
    });
    println!("{s_all}");

    // exec-only accounting from the executable's internal stats
    let exec = engine.load("listops", "skyformer", "train", false).unwrap();
    let st = exec.stats.borrow();
    if st.calls > 0 {
        let exec_ms = st.exec_seconds / st.calls as f64 * 1e3;
        let upload_ms = st.upload_seconds / st.calls as f64 * 1e3;
        let download_ms = st.download_seconds / st.calls as f64 * 1e3;
        let total = s_all.mean_ms();
        println!(
            "\nper-step decomposition: execute {exec_ms:.1}ms, upload {upload_ms:.1}ms, \
             download {download_ms:.1}ms, other {:.1}ms",
            (total - exec_ms - upload_ms - download_ms).max(0.0)
        );
        println!(
            "coordinator overhead: {:.1}% of step (target < 5%)",
            100.0 * (total - exec_ms) / total
        );
    }
}
