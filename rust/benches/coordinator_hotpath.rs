//! L3 hot-path microbenchmarks (§Perf): where does coordinator time go?
//!
//! Decomposes one train step into: batch generation, tensor->literal
//! upload, execute, download.  The §Perf target is coordinator overhead
//! (everything but execute) < 5% of step time.

use std::time::Duration;

use skyformer::coordinator::trainer::{TrainConfig, Trainer};
use skyformer::data::batch::{Dataset, Split};
use skyformer::runtime::engine::Engine;
use skyformer::runtime::tensor::Tensor;
use skyformer::util::bench::bench;

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let engine = match Engine::new(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("coordinator_hotpath: skipped ({e})");
            return;
        }
    };
    let Ok(spec) = engine
        .manifest()
        .find("listops", "skyformer", "train", false)
        .cloned()
    else {
        eprintln!("coordinator_hotpath: listops_skyformer not built");
        return;
    };

    // 1. batch generation
    let ds = Dataset::for_task(&spec.task_config, 0).unwrap();
    let mut i = 0u64;
    let s = bench("data: batch generation", Duration::from_secs(2), || {
        let b = ds.batch(Split::Train, i);
        std::hint::black_box(b);
        i += 1;
    });
    println!("{s}");

    // 2. host->literal conversion for one full input set
    let init = engine.load("listops", "skyformer", "init", false).unwrap();
    let state = init.run(&[Tensor::scalar_u32(0)]).unwrap();
    let batch = ds.batch(Split::Train, 0);
    let s = bench("runtime: tensors -> literals", Duration::from_secs(2), || {
        for t in &state {
            std::hint::black_box(t.to_literal().unwrap());
        }
        std::hint::black_box(batch.tokens.to_literal().unwrap());
    });
    println!("{s}");

    // 3. full step through the Trainer (execute dominates)
    let cfg = TrainConfig::new("listops", "skyformer");
    let mut trainer = Trainer::new(&engine, cfg).unwrap();
    let _ = trainer.step(0);
    let mut step = 1usize;
    let s_all = bench("trainer: full step", Duration::from_secs(8), || {
        trainer.step(step).unwrap();
        step += 1;
    });
    println!("{s_all}");

    // 4. exec-only accounting from the executable's internal stats
    let exec = engine.load("listops", "skyformer", "train", false).unwrap();
    let st = exec.stats.borrow();
    if st.calls > 0 {
        let exec_ms = st.exec_seconds / st.calls as f64 * 1e3;
        let upload_ms = st.upload_seconds / st.calls as f64 * 1e3;
        let download_ms = st.download_seconds / st.calls as f64 * 1e3;
        let total = s_all.mean_ms();
        println!(
            "\nper-step decomposition: execute {exec_ms:.1}ms, upload {upload_ms:.1}ms, \
             download {download_ms:.1}ms, other {:.1}ms",
            (total - exec_ms - upload_ms - download_ms).max(0.0)
        );
        println!(
            "coordinator overhead: {:.1}% of step (target < 5%)",
            100.0 * (total - exec_ms) / total
        );
    }
}
