//! Table 2 bench: per-train-step wall time and peak tensor memory for each
//! (attention, task) artifact that has been built.
//!
//! Regenerates the paper's Table 2 *shape*: which attention is cheaper per
//! step and how cost scales with sequence length (absolute hours are
//! testbed-specific; DESIGN.md §5).  Run via `cargo bench --bench
//! table2_time` (custom harness — criterion is unavailable offline).
//!
//! Always emits a `BENCH_table2.json` artifact (override with `--out`)
//! carrying the measured rows, an offline `kernel_compare` section
//! (scalar oracle vs tiled kernel on attention-sized shapes — the
//! single-machine analogue of the table's time column), and the obs
//! metrics snapshot, so CI can diff bench runs; without `--features
//! pjrt` the trainer rows are empty but the artifact is still written.
//! `--obs-out PREFIX` additionally dumps the full trace/metrics fileset.

use std::time::Duration;

use skyformer::kernels::{self, ops::reference, KernelCtx};
use skyformer::linalg::Matrix;
use skyformer::util::args::Args;
use skyformer::util::bench::{bench, Stats};
use skyformer::util::json::{self, Value};
use skyformer::util::rng::Rng;

fn main() {
    let args = Args::from_env();
    let obs_out = skyformer::obs::init_from_env()
        .or_else(|| args.get("obs-out").map(|s| s.to_string()));
    if obs_out.is_some() {
        skyformer::obs::set_enabled(true);
    }

    let rows = run_rows();
    if rows.is_empty() {
        eprintln!("table2_time: no measurements (missing pjrt feature or artifacts)");
    }

    let artifact = json::obj(vec![
        ("bench", json::s("table2_time")),
        ("rows", Value::Array(rows)),
        ("kernel_compare", Value::Array(kernel_compare_rows())),
        ("metrics", skyformer::obs::snapshot().to_json()),
    ]);
    let out_path = args.get_or("out", "BENCH_table2.json").to_string();
    match std::fs::write(&out_path, json::to_string(&artifact)) {
        Ok(()) => println!("bench artifact written to {out_path}"),
        Err(e) => eprintln!("table2_time: cannot write {out_path}: {e}"),
    }

    if let Some(prefix) = obs_out {
        match skyformer::obs::dump(&prefix) {
            Ok(paths) => eprintln!("obs: wrote {}", paths.join(", ")),
            Err(e) => eprintln!("obs: dump failed: {e}"),
        }
    }
}

/// Offline scalar-vs-kernel comparison on the shapes one attention head
/// sees (n tokens, p channels): the kernel-subsystem time series CI
/// tracks alongside the trainer rows.
fn kernel_compare_rows() -> Vec<Value> {
    let (n, p) = (128usize, 32usize);
    let ctx = KernelCtx::global();
    let mut rng = Rng::new(42);
    let q = Matrix::randn(&mut rng, n, p, 0.5);
    let k = Matrix::randn(&mut rng, n, p, 0.5);
    let v = Matrix::randn(&mut rng, n, p, 1.0);
    let s = kernels::matmul_transb(KernelCtx::with_threads(1), &q, &k);
    let budget = Duration::from_millis(300);

    let mut rows = Vec::new();
    let mut push = |kernel: &'static str, series: &'static str, stats: Stats| {
        let mut row = stats.to_json();
        if let Value::Object(map) = &mut row {
            map.insert("kernel".into(), json::s(kernel));
            map.insert("series".into(), json::s(series));
            map.insert("n".into(), json::num(n as f64));
            map.insert("threads".into(), json::num(ctx.threads as f64));
        }
        rows.push(row);
    };
    push(
        "gaussian_scores",
        "scalar",
        bench("kernel_compare: gaussian_scores scalar", budget, || {
            std::hint::black_box(reference::gaussian_scores(&q, &k));
        }),
    );
    push(
        "gaussian_scores",
        "kernel",
        bench("kernel_compare: gaussian_scores kernel", budget, || {
            std::hint::black_box(kernels::gaussian_scores(ctx, &q, &k));
        }),
    );
    push(
        "row_softmax_matmul",
        "scalar",
        bench("kernel_compare: row_softmax_matmul scalar", budget, || {
            std::hint::black_box(reference::row_softmax_matmul(&s, &v));
        }),
    );
    push(
        "row_softmax_matmul",
        "kernel",
        bench("kernel_compare: row_softmax_matmul kernel", budget, || {
            std::hint::black_box(kernels::row_softmax_matmul(ctx, &s, &v));
        }),
    );
    push(
        "matmul",
        "scalar",
        bench("kernel_compare: matmul scalar", budget, || {
            std::hint::black_box(reference::matmul(&s, &s));
        }),
    );
    push(
        "matmul",
        "kernel",
        bench("kernel_compare: matmul kernel", budget, || {
            std::hint::black_box(kernels::matmul(ctx, &s, &s));
        }),
    );
    rows
}

#[cfg(not(feature = "pjrt"))]
fn run_rows() -> Vec<Value> {
    Vec::new()
}

#[cfg(feature = "pjrt")]
fn run_rows() -> Vec<Value> {
    use std::time::Duration;

    use skyformer::coordinator::trainer::{TrainConfig, Trainer};
    use skyformer::report::tables::{fmt_bytes, Table};
    use skyformer::runtime::engine::Engine;

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let engine = match Engine::new(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("table2_time: skipped ({e})");
            return Vec::new();
        }
    };
    let configs = engine.manifest().trainable_configs();
    if configs.is_empty() {
        eprintln!("table2_time: no trainable artifacts built");
        return Vec::new();
    }
    let mut t = Table::new(
        "Table 2 (bench): per-step time / peak tensor bytes",
        &["task", "model", "mean ms/step", "p95 ms", "peak mem", "n"],
    );
    let mut rows = Vec::new();
    for (task, attn, pallas) in configs {
        if pallas {
            continue; // interpret-mode pallas timing is not a perf claim
        }
        let cfg = TrainConfig::new(&task, &attn);
        let mut trainer = match Trainer::new(&engine, cfg) {
            Ok(tr) => tr,
            Err(e) => {
                eprintln!("skip {task}/{attn}: {e}");
                continue;
            }
        };
        // warmup (compile + caches)
        let mut step = 0usize;
        let _ = trainer.step(step);
        step += 1;
        let stats = skyformer::util::bench::bench(
            &format!("{task}/{attn}"),
            Duration::from_secs(6),
            || {
                trainer.step(step).expect("train step");
                step += 1;
            },
        );
        println!("{stats}");
        t.row(vec![
            task.clone(),
            attn.clone(),
            format!("{:.1}", stats.mean_ms()),
            format!("{:.1}", stats.p95.as_secs_f64() * 1e3),
            fmt_bytes(trainer.metrics.peak_bytes),
            stats.iters.to_string(),
        ]);
        let mut row = stats.to_json();
        if let Value::Object(map) = &mut row {
            map.insert("task".into(), json::s(task.clone()));
            map.insert("attention".into(), json::s(attn.clone()));
            map.insert(
                "peak_bytes".into(),
                json::num(trainer.metrics.peak_bytes as f64),
            );
        }
        rows.push(row);
    }
    println!("\n{}", t.render());
    rows
}
