//! Table 2 bench: per-train-step wall time and peak tensor memory for each
//! (attention, task) artifact that has been built.
//!
//! Regenerates the paper's Table 2 *shape*: which attention is cheaper per
//! step and how cost scales with sequence length (absolute hours are
//! testbed-specific; DESIGN.md §5).  Run via `cargo bench --bench
//! table2_time` (custom harness — criterion is unavailable offline).

use std::time::Duration;

use skyformer::coordinator::trainer::{TrainConfig, Trainer};
use skyformer::report::tables::{fmt_bytes, Table};
use skyformer::runtime::engine::Engine;

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let engine = match Engine::new(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("table2_time: skipped ({e})");
            return;
        }
    };
    let configs = engine.manifest().trainable_configs();
    if configs.is_empty() {
        eprintln!("table2_time: no trainable artifacts built");
        return;
    }
    let mut t = Table::new(
        "Table 2 (bench): per-step time / peak tensor bytes",
        &["task", "model", "mean ms/step", "p95 ms", "peak mem", "n"],
    );
    for (task, attn, pallas) in configs {
        if pallas {
            continue; // interpret-mode pallas timing is not a perf claim
        }
        let cfg = TrainConfig::new(&task, &attn);
        let mut trainer = match Trainer::new(&engine, cfg) {
            Ok(tr) => tr,
            Err(e) => {
                eprintln!("skip {task}/{attn}: {e}");
                continue;
            }
        };
        // warmup (compile + caches)
        let mut step = 0usize;
        let _ = trainer.step(step);
        step += 1;
        let stats = skyformer::util::bench::bench(
            &format!("{task}/{attn}"),
            Duration::from_secs(6),
            || {
                trainer.step(step).expect("train step");
                step += 1;
            },
        );
        println!("{stats}");
        t.row(vec![
            task.clone(),
            attn.clone(),
            format!("{:.1}", stats.mean_ms()),
            format!("{:.1}", stats.p95.as_secs_f64() * 1e3),
            fmt_bytes(trainer.metrics.peak_bytes),
            stats.iters.to_string(),
        ]);
    }
    println!("\n{}", t.render());
}
