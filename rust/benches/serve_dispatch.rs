//! Serving dispatch bench: batched multi-head dispatch (one pool job
//! per batch) vs per-request dispatch (one pool job per head), across
//! batch sizes — the number the ROADMAP's "batched multi-head dispatch"
//! item exists to win.
//!
//! Run via `cargo bench --bench serve_dispatch` (custom harness).
//! Always writes `BENCH_serve_dispatch.json` (override with `--out`)
//! with per-(kind, batch) rows for both series plus the obs metrics
//! snapshot.  Bitwise equality of the two series is asserted here too —
//! a perf number for a wrong result is worse than no number.

use std::time::Duration;

use skyformer::attention::exact;
use skyformer::kernels::{self, AttnItem, KernelCtx};
use skyformer::linalg::Matrix;
use skyformer::serve::ModelKind;
use skyformer::util::args::Args;
use skyformer::util::bench::bench;
use skyformer::util::json::{self, Value};
use skyformer::util::rng::Rng;

fn main() {
    let args = Args::from_env();
    let obs_out =
        skyformer::obs::init_from_env().or_else(|| args.get("obs-out").map(|s| s.to_string()));
    if obs_out.is_some() {
        skyformer::obs::set_enabled(true);
    }

    let n = args.get_usize("seq", 128).expect("--seq");
    let p = args.get_usize("dim", 32).expect("--dim");
    let heads = args.get_usize("heads", 2).expect("--heads");
    let budget = Duration::from_millis(args.get_u64("budget-ms", 300).expect("--budget-ms"));
    let ctx = KernelCtx::global();
    let mut rng = Rng::new(args.get_u64("seed", 42).expect("--seed"));

    let mut rows = Vec::new();
    for kind in [ModelKind::Exact, ModelKind::Kernelized] {
        for &batch in &[1usize, 2, 4, 8, 16] {
            // batch requests x heads independent attention problems
            let data: Vec<[Matrix; 3]> = (0..batch * heads)
                .map(|_| {
                    [
                        Matrix::randn(&mut rng, n, p, 0.5),
                        Matrix::randn(&mut rng, n, p, 0.5),
                        Matrix::randn(&mut rng, n, p, 1.0),
                    ]
                })
                .collect();
            let items: Vec<AttnItem> =
                data.iter().map(|[q, k, v]| AttnItem { q, k, v }).collect();

            let batched_out = run_batched(ctx, kind, &items);
            let unbatched_out = run_unbatched(ctx, kind, &data);
            for (a, b) in batched_out.iter().zip(&unbatched_out) {
                assert_eq!(
                    kernels::digest(a),
                    kernels::digest(b),
                    "batched != unbatched ({kind:?}, batch {batch})"
                );
            }

            let label_b = format!("{} batched x{batch}", kind.name());
            let sb = bench(&label_b, budget, || {
                std::hint::black_box(run_batched(ctx, kind, &items));
            });
            let label_u = format!("{} unbatched x{batch}", kind.name());
            let su = bench(&label_u, budget, || {
                std::hint::black_box(run_unbatched(ctx, kind, &data));
            });
            println!(
                "{}: batch {batch:>2}: batched {:.3} ms  unbatched {:.3} ms  ({:.2}x)",
                kind.name(),
                sb.mean_ms(),
                su.mean_ms(),
                su.mean_ms() / sb.mean_ms().max(1e-9)
            );
            for (series, stats) in [("batched", sb), ("unbatched", su)] {
                let mut row = stats.to_json();
                if let Value::Object(map) = &mut row {
                    map.insert("kind".into(), json::s(kind.name()));
                    map.insert("series".into(), json::s(series));
                    map.insert("batch".into(), json::num(batch as f64));
                    map.insert("heads".into(), json::num(heads as f64));
                    map.insert("seq".into(), json::num(n as f64));
                    map.insert("threads".into(), json::num(ctx.threads as f64));
                    map.insert("pool".into(), json::s(ctx.mode.name()));
                }
                rows.push(row);
            }
        }
    }

    let artifact = json::obj(vec![
        ("bench", json::s("serve_dispatch")),
        ("rows", Value::Array(rows)),
        ("metrics", skyformer::obs::snapshot().to_json()),
    ]);
    let out_path = args.get_or("out", "BENCH_serve_dispatch.json").to_string();
    match std::fs::write(&out_path, json::to_string(&artifact)) {
        Ok(()) => println!("bench artifact written to {out_path}"),
        Err(e) => eprintln!("serve_dispatch: cannot write {out_path}: {e}"),
    }

    if let Some(prefix) = obs_out {
        match skyformer::obs::dump(&prefix) {
            Ok(paths) => eprintln!("obs: wrote {}", paths.join(", ")),
            Err(e) => eprintln!("obs: dump failed: {e}"),
        }
    }
}

fn run_batched(ctx: KernelCtx, kind: ModelKind, items: &[AttnItem]) -> Vec<Matrix> {
    match kind {
        ModelKind::Exact => kernels::batched_softmax_attention(ctx, items),
        ModelKind::Kernelized => kernels::batched_kernelized_attention(ctx, items),
    }
}

fn run_unbatched(ctx: KernelCtx, kind: ModelKind, data: &[[Matrix; 3]]) -> Vec<Matrix> {
    data.iter()
        .map(|[q, k, v]| match kind {
            ModelKind::Exact => exact::softmax_attention_in(ctx, q, k, v),
            ModelKind::Kernelized => exact::kernelized_attention_in(ctx, q, k, v),
        })
        .collect()
}
