//! Serving dispatch bench: batched multi-head dispatch (one pool job
//! per batch) vs per-request dispatch (one pool job per head), across
//! batch sizes — the number the ROADMAP's "batched multi-head dispatch"
//! item exists to win — plus an end-to-end `Server` section across
//! dispatcher shard counts under mixed-bucket load (the number the
//! sharding item exists to win: gather-side head-of-line blocking).
//!
//! Run via `cargo bench --bench serve_dispatch` (custom harness).
//! Always writes `BENCH_serve_dispatch.json` (override with `--out`)
//! with per-(kind, batch) rows for both series, per-dispatcher-count
//! end-to-end rows, plus the obs metrics snapshot.  Bitwise equality of
//! the two kernel series is asserted here too — a perf number for a
//! wrong result is worse than no number.

use std::time::{Duration, Instant};

use skyformer::attention::exact;
use skyformer::kernels::{self, AttnItem, KernelCtx};
use skyformer::linalg::Matrix;
use skyformer::serve::{Head, ModelKind, Outcome, Priority, Request, ServeConfig, Server};
use skyformer::util::args::Args;
use skyformer::util::bench::bench;
use skyformer::util::json::{self, Value};
use skyformer::util::rng::Rng;

fn main() {
    let args = Args::from_env();
    let obs_out =
        skyformer::obs::init_from_env().or_else(|| args.get("obs-out").map(|s| s.to_string()));
    if obs_out.is_some() {
        skyformer::obs::set_enabled(true);
    }

    let n = args.get_usize("seq", 128).expect("--seq");
    let p = args.get_usize("dim", 32).expect("--dim");
    let heads = args.get_usize("heads", 2).expect("--heads");
    let budget = Duration::from_millis(args.get_u64("budget-ms", 300).expect("--budget-ms"));
    let ctx = KernelCtx::global();
    let mut rng = Rng::new(args.get_u64("seed", 42).expect("--seed"));

    let mut rows = Vec::new();
    for kind in [ModelKind::Exact, ModelKind::Kernelized] {
        for &batch in &[1usize, 2, 4, 8, 16] {
            // batch requests x heads independent attention problems
            let data: Vec<[Matrix; 3]> = (0..batch * heads)
                .map(|_| {
                    [
                        Matrix::randn(&mut rng, n, p, 0.5),
                        Matrix::randn(&mut rng, n, p, 0.5),
                        Matrix::randn(&mut rng, n, p, 1.0),
                    ]
                })
                .collect();
            let items: Vec<AttnItem> =
                data.iter().map(|[q, k, v]| AttnItem { q, k, v }).collect();

            let batched_out = run_batched(ctx, kind, &items);
            let unbatched_out = run_unbatched(ctx, kind, &data);
            for (a, b) in batched_out.iter().zip(&unbatched_out) {
                assert_eq!(
                    kernels::digest(a),
                    kernels::digest(b),
                    "batched != unbatched ({kind:?}, batch {batch})"
                );
            }

            let label_b = format!("{} batched x{batch}", kind.name());
            let sb = bench(&label_b, budget, || {
                std::hint::black_box(run_batched(ctx, kind, &items));
            });
            let label_u = format!("{} unbatched x{batch}", kind.name());
            let su = bench(&label_u, budget, || {
                std::hint::black_box(run_unbatched(ctx, kind, &data));
            });
            println!(
                "{}: batch {batch:>2}: batched {:.3} ms  unbatched {:.3} ms  ({:.2}x)",
                kind.name(),
                sb.mean_ms(),
                su.mean_ms(),
                su.mean_ms() / sb.mean_ms().max(1e-9)
            );
            for (series, stats) in [("batched", sb), ("unbatched", su)] {
                let mut row = stats.to_json();
                if let Value::Object(map) = &mut row {
                    map.insert("kind".into(), json::s(kind.name()));
                    map.insert("series".into(), json::s(series));
                    map.insert("batch".into(), json::num(batch as f64));
                    map.insert("heads".into(), json::num(heads as f64));
                    map.insert("seq".into(), json::num(n as f64));
                    map.insert("threads".into(), json::num(ctx.threads as f64));
                    map.insert("pool".into(), json::s(ctx.mode.name()));
                }
                rows.push(row);
            }
        }
    }

    // end-to-end: the full Server pipeline (admission → shard gather →
    // single-submitter dispatch) under mixed-bucket mixed-lane load,
    // across dispatcher shard counts.  One run = submit-and-drain of a
    // fixed request set; the shard win is gather-side, so it shows up
    // as wall-clock per drained set, not per kernel call.
    let e2e_requests = args.get_usize("e2e-requests", 64).expect("--e2e-requests");
    let gen_request = |id: u64| -> Request {
        let mut r = Rng::new(7).split(id);
        let kind = if r.below(2) == 0 { ModelKind::Exact } else { ModelKind::Kernelized };
        let (sn, sp) = if r.below(2) == 0 { (n, p) } else { (n / 2, p) };
        let heads: Vec<Head> = (0..heads)
            .map(|_| Head {
                q: Matrix::randn(&mut r, sn, sp, 0.5),
                k: Matrix::randn(&mut r, sn, sp, 0.5),
                v: Matrix::randn(&mut r, sn, sp, 1.0),
            })
            .collect();
        let priority = if r.below(4) == 0 { Priority::High } else { Priority::Normal };
        Request { id, kind, heads, deadline: None, priority }
    };
    let requests: Vec<Request> = (0..e2e_requests as u64).map(gen_request).collect();
    for dispatchers in [1usize, 2, 4] {
        let run = || {
            let cfg = ServeConfig {
                queue_capacity: e2e_requests.max(1),
                max_batch: 8,
                max_wait: Duration::from_micros(200),
                dispatchers,
                ..ServeConfig::default()
            };
            let server = Server::start(cfg, ctx);
            let tickets: Vec<_> = requests
                .iter()
                .map(|r| server.submit(r.clone()).expect("bench admission"))
                .collect();
            for t in &tickets {
                assert!(matches!(t.wait(), Outcome::Completed { .. }), "bench request lost");
            }
            server.shutdown();
        };
        // warm + measure by hand: one Server per iteration is the unit
        run();
        let t0 = Instant::now();
        let iters = 3usize;
        for _ in 0..iters {
            run();
        }
        let per_drain_ms = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;
        println!(
            "e2e: dispatchers {dispatchers}: {per_drain_ms:.3} ms per {e2e_requests}-request drain \
             ({:.0} req/s)",
            e2e_requests as f64 / (per_drain_ms / 1e3).max(1e-9)
        );
        rows.push(json::obj(vec![
            ("kind", json::s("mixed")),
            ("series", json::s("server_e2e")),
            ("dispatchers", json::num(dispatchers as f64)),
            ("requests", json::num(e2e_requests as f64)),
            ("heads", json::num(heads as f64)),
            ("seq", json::num(n as f64)),
            ("threads", json::num(ctx.threads as f64)),
            ("pool", json::s(ctx.mode.name())),
            ("mean_ms", json::num(per_drain_ms)),
            (
                "throughput_rps",
                json::num(e2e_requests as f64 / (per_drain_ms / 1e3).max(1e-9)),
            ),
        ]));
    }

    let artifact = json::obj(vec![
        ("bench", json::s("serve_dispatch")),
        ("rows", Value::Array(rows)),
        ("metrics", skyformer::obs::snapshot().to_json()),
    ]);
    let out_path = args.get_or("out", "BENCH_serve_dispatch.json").to_string();
    match std::fs::write(&out_path, json::to_string(&artifact)) {
        Ok(()) => println!("bench artifact written to {out_path}"),
        Err(e) => eprintln!("serve_dispatch: cannot write {out_path}: {e}"),
    }

    if let Some(prefix) = obs_out {
        match skyformer::obs::dump(&prefix) {
            Ok(paths) => eprintln!("obs: wrote {}", paths.join(", ")),
            Err(e) => eprintln!("obs: dump failed: {e}"),
        }
    }
}

fn run_batched(ctx: KernelCtx, kind: ModelKind, items: &[AttnItem]) -> Vec<Matrix> {
    match kind {
        ModelKind::Exact => kernels::batched_softmax_attention(ctx, items),
        ModelKind::Kernelized => kernels::batched_kernelized_attention(ctx, items),
    }
}

fn run_unbatched(ctx: KernelCtx, kind: ModelKind, data: &[[Matrix; 3]]) -> Vec<Matrix> {
    data.iter()
        .map(|[q, k, v]| match kind {
            ModelKind::Exact => exact::softmax_attention_in(ctx, q, k, v),
            ModelKind::Kernelized => exact::kernelized_attention_in(ctx, q, k, v),
        })
        .collect()
}
