//! Figure 1 bench: relative spectral error vs number of features, for all
//! approximation methods, across sequence lengths and weight regimes —
//! plus the wall-time cost of each method at each budget.
//!
//! Prints the same series the paper plots (error should fall sharply with
//! d for Skyformer and stay nearly flat for the others), for both
//! "initialized" and "pretrained" Q/K/V regimes (DESIGN.md §5 probes).

use skyformer::attention::{self, exact, probes};
use skyformer::linalg::norms;
use skyformer::report::tables::Table;
use skyformer::util::bench::time_once;
use skyformer::util::rng::Rng;

fn main() {
    skyformer::obs::init_from_env();
    let features = [16usize, 32, 64, 128, 256];
    let lengths = [256usize, 512];
    let trials = 3u64;
    let p = 32;

    for regime in [probes::Regime::Init, probes::Regime::Pretrained] {
        for &n in &lengths {
            let mut err_t = Table::new(
                &format!(
                    "Figure 1 (bench): rel spectral error, n={n}, {} weights",
                    regime.name()
                ),
                &["method", "d=16", "d=32", "d=64", "d=128", "d=256"],
            );
            let mut time_t = Table::new(
                &format!("Figure 1 (bench): approx wall ms, n={n}, {}", regime.name()),
                &["method", "d=16", "d=32", "d=64", "d=128", "d=256"],
            );
            let mut rng = Rng::new(42).split_str(regime.name()).split(n as u64);
            let pr = probes::probe(regime, n, p, &mut rng);
            let target = exact::softmax_attention(&pr.q, &pr.k, &pr.v);

            for method in attention::METHODS {
                let mut err_cells = vec![method.name().to_string()];
                let mut time_cells = vec![method.name().to_string()];
                for &d in &features {
                    let mut err_acc = 0.0f32;
                    let mut ms_acc = 0.0f64;
                    for trial in 0..trials {
                        let mut trng = rng.split(d as u64 * 101 + trial);
                        let (approx, dt) = time_once(|| {
                            attention::approximate(method, &pr.q, &pr.k, &pr.v, d, &mut trng)
                        });
                        err_acc += norms::relative_spectral_error(&target, &approx);
                        ms_acc += dt.as_secs_f64() * 1e3;
                    }
                    err_cells.push(format!("{:.4}", err_acc / trials as f32));
                    time_cells.push(format!("{:.1}", ms_acc / trials as f64));
                }
                err_t.row(err_cells);
                time_t.row(time_cells);
            }
            println!("{}", err_t.render());
            println!("{}", time_t.render());
        }
    }
    match skyformer::obs::finish(None) {
        Ok(paths) if !paths.is_empty() => eprintln!("obs: wrote {}", paths.join(", ")),
        Ok(_) => {}
        Err(e) => eprintln!("obs: dump failed: {e}"),
    }
}
