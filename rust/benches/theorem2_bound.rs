//! Theorem 2 empirics: measured Nyström error vs the lambda = eps||C||
//! bound, and the statistical-dimension/landmark-count relationship.
//!
//! For a sweep of regularisation levels lambda, we compute the statistical
//! dimension d_stat(lambda) of the lifted matrix C_bar, sample the
//! theorem's sufficient landmark count, and verify the measured
//! ||C - C_tilde|| stays below lambda — the paper's §4.3 guarantee.

use skyformer::linalg::{norms, Matrix};
use skyformer::nystrom::{self, theory, Inverse, Kernel};
use skyformer::report::tables::Table;
use skyformer::util::rng::Rng;

fn main() {
    skyformer::obs::init_from_env();
    let n = 128usize;
    let p = 16usize;
    let mut rng = Rng::new(7);
    let scale = (p as f32).powf(-0.25) * 0.8;
    let q = Matrix::randn(&mut rng, n, p, scale);
    let k = Matrix::randn(&mut rng, n, p, scale);
    let x = q.vcat(&k);
    let c = nystrom::kernel_matrix(Kernel::Gaussian, &q, &k);
    let cbar = nystrom::kernel_matrix(Kernel::Gaussian, &x, &x);
    let norm_c = norms::spectral_norm(&c);
    println!("n={n} p={p}  ||C||={norm_c:.4}\n");

    let mut t = Table::new(
        "Theorem 2 (bench): measured error vs lambda bound",
        &[
            "eps", "lambda", "d_stat", "beta", "d_suff", "d_used",
            "measured ||C-C~||", "bound ok",
        ],
    );
    for eps in [0.5f32, 0.25, 0.1, 0.05] {
        let lambda = eps * norm_c;
        let prof = theory::leverage_profile(&cbar, lambda);
        let beta = theory::coherence_beta(&prof);
        let d_suff = theory::sufficient_landmarks(&prof);
        // theorem's d can exceed 2n for small eps; cap at 2n (exact regime)
        let d_used = d_suff.min(2 * n);
        let mut worst = 0.0f32;
        for trial in 0..5u64 {
            let mut trng = rng.split(trial + eps.to_bits() as u64);
            let approx = nystrom::modified_nystrom(
                Kernel::Gaussian,
                &q,
                &k,
                d_used,
                Inverse::Exact { gamma: lambda * 1e-3 },
                &mut trng,
            );
            let err = norms::spectral_norm(&c.sub(&approx));
            worst = worst.max(err);
        }
        t.row(vec![
            format!("{eps}"),
            format!("{lambda:.4}"),
            format!("{:.1}", prof.d_stat),
            format!("{beta:.3}"),
            d_suff.to_string(),
            d_used.to_string(),
            format!("{worst:.4}"),
            if worst <= lambda * 1.05 { "yes".into() } else { "VIOLATED".to_string() },
        ]);
    }
    println!("{}", t.render());

    // d_stat growth with 1/eps (the paper's complexity discussion)
    let mut t2 = Table::new(
        "Statistical dimension vs regularisation",
        &["lambda", "d_stat", "d_stat / 2n"],
    );
    for lam in [1.0f32, 0.3, 0.1, 0.03, 0.01] {
        let prof = theory::leverage_profile(&cbar, lam);
        t2.row(vec![
            format!("{lam}"),
            format!("{:.1}", prof.d_stat),
            format!("{:.3}", prof.d_stat / (2.0 * n as f32)),
        ]);
    }
    println!("{}", t2.render());
    match skyformer::obs::finish(None) {
        Ok(paths) if !paths.is_empty() => eprintln!("obs: wrote {}", paths.join(", ")),
        Ok(_) => {}
        Err(e) => eprintln!("obs: dump failed: {e}"),
    }
}
