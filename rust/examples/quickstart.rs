//! Quickstart: load an AOT artifact, initialise a model, run one forward
//! (eval) pass and one training step — the whole three-layer stack in
//! ~40 lines of user code.
//!
//! ```bash
//! make artifacts            # once (python, build time)
//! cargo run --release --example quickstart
//! ```
//!
//! Uses the *pallas* artifact when present, proving the L1 Pallas kernels
//! execute through the PJRT path end to end.

use skyformer::data::batch::{Dataset, Split};
use skyformer::runtime::engine::Engine;
use skyformer::runtime::tensor::Tensor;

fn main() -> skyformer::Result<()> {
    let engine = Engine::new("artifacts")?;
    println!("PJRT platform: {}", engine.platform());

    // prefer the pallas-lowered artifact; fall back to the fused one
    let pallas = engine
        .manifest()
        .find("listops", "skyformer", "train", true)
        .is_ok();
    println!("using {} lowering", if pallas { "pallas" } else { "fused" });

    // 1. initialise params + optimizer in-graph (seeded)
    let init = engine.load("listops", "skyformer", "init", pallas)?;
    let state = init.run(&[Tensor::scalar_u32(0)])?;
    println!("initialised {} state tensors", state.len());

    // 2. generate a deterministic synthetic ListOps batch (pure rust)
    let task = init.spec.task_config.clone();
    let dataset = Dataset::for_task(&task, 0)?;
    let batch = dataset.batch(Split::Train, 0);
    println!(
        "batch: tokens {:?}, labels {:?}",
        batch.tokens.shape(),
        batch.labels.shape()
    );

    // 3. forward pass (eval artifact): loss + accuracy of the random model
    let eval = engine.load("listops", "skyformer", "eval", pallas)?;
    let n_p = eval.spec.num_params;
    let mut inputs: Vec<Tensor> = state[..n_p].to_vec();
    inputs.push(batch.tokens.clone());
    inputs.push(batch.labels.clone());
    inputs.push(Tensor::scalar_u32(0));
    let out = eval.run(&inputs)?;
    println!(
        "random model: loss {:.4}, acc {:.3} (chance = 0.1)",
        out[0].scalar_value_f32()?,
        out[1].scalar_value_f32()?
    );

    // 4. one training step (fwd + bwd + Adam, one HLO module)
    let train = engine.load("listops", "skyformer", "train", pallas)?;
    let mut inputs: Vec<Tensor> = state.clone();
    inputs.push(batch.tokens);
    inputs.push(batch.labels);
    inputs.push(Tensor::scalar_u32(0));
    inputs.push(Tensor::scalar_f32(1e-4));
    let out = train.run(&inputs)?;
    let acc = out[out.len() - 1].scalar_value_f32()?;
    let loss = out[out.len() - 2].scalar_value_f32()?;
    println!("after 1 train step: loss {loss:.4}, acc {acc:.3}");
    println!("quickstart OK");
    Ok(())
}
