//! Figure-1 reproduction: matrix-approximation study.
//!
//! For each weight regime (initialized / pretrained-like) and sequence
//! length, every approximation method approximates the exact softmax
//! self-attention output on the same (Q, K, V); we report the relative
//! spectral-norm error as the number of features grows — the paper's
//! claim is that only the modified-Nyström ("Skyformer") series improves
//! sharply with d.
//!
//! Pure rust (native attention substrate) — no artifacts needed.
//!
//! ```bash
//! cargo run --release --example approx_study -- --n 256,512 --trials 3
//! ```

use skyformer::attention::{self, approximators, exact, probes};
use skyformer::linalg::norms;
use skyformer::report::tables::Table;
use skyformer::util::args::Args;
use skyformer::util::rng::Rng;

fn main() -> skyformer::Result<()> {
    let args = Args::from_env();
    skyformer::obs::init_from_env();
    if args.get("obs-out").is_some() {
        skyformer::obs::set_enabled(true);
    }
    let lengths: Vec<usize> = args
        .get_list("n")
        .unwrap_or_else(|| vec!["256".into(), "512".into()])
        .iter()
        .map(|s| s.parse().unwrap_or(256))
        .collect();
    let features: Vec<usize> = args
        .get_list("features")
        .unwrap_or_else(|| {
            vec!["16".into(), "32".into(), "64".into(), "128".into(), "256".into()]
        })
        .iter()
        .map(|s| s.parse().unwrap_or(64))
        .collect();
    let trials = args.get_u64("trials", 3)?;
    let p = args.get_usize("p", 32)?;
    let seed = args.get_u64("seed", 0)?;

    for regime in [probes::Regime::Init, probes::Regime::Pretrained] {
        for &n in &lengths {
            let mut headers = vec!["method".to_string()];
            headers.extend(features.iter().map(|f| format!("d={f}")));
            let refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
            let mut t = Table::new(
                &format!(
                    "Figure 1: rel spectral error vs features (n={n}, {} weights)",
                    regime.name()
                ),
                &refs,
            );
            let mut rng = Rng::new(seed).split_str(regime.name()).split(n as u64);
            let pr = probes::probe(regime, n, p, &mut rng);
            let target = exact::softmax_attention(&pr.q, &pr.k, &pr.v);

            for method in attention::METHODS {
                let mut cells = vec![method.name().to_string()];
                for &d in &features {
                    let mut acc = 0.0f32;
                    for trial in 0..trials {
                        let mut trng = rng.split(d as u64 * 7919 + trial);
                        let approx =
                            attention::approximate(method, &pr.q, &pr.k, &pr.v, d, &mut trng);
                        acc += norms::relative_spectral_error(&target, &approx);
                    }
                    cells.push(format!("{:.4}", acc / trials as f32));
                }
                t.row(cells);
            }
            println!("{}", t.render());

            // companion series: the true Skyformer target — approximating
            // Kernelized Attention with the Gaussian-kernel lift (§4.5)
            let ka_target = exact::kernelized_attention(&pr.q, &pr.k, &pr.v);
            let mut t2 = Table::new(
                &format!(
                    "Skyformer vs its own target (Kernelized Attention), n={n}, {}",
                    regime.name()
                ),
                &refs,
            );
            let mut cells = vec!["skyformer->KA".to_string()];
            for &d in &features {
                let mut acc = 0.0f32;
                for trial in 0..trials {
                    let mut trng = rng.split(d as u64 * 104729 + trial);
                    let approx =
                        approximators::skyformer_gaussian(&pr.q, &pr.k, &pr.v, d, &mut trng);
                    acc += norms::relative_spectral_error(&ka_target, &approx);
                }
                cells.push(format!("{:.4}", acc / trials as f32));
            }
            t2.row(cells);
            println!("{}", t2.render());
        }
    }
    match skyformer::obs::finish(args.get("obs-out")) {
        Ok(paths) if !paths.is_empty() => eprintln!("obs: wrote {}", paths.join(", ")),
        Ok(_) => {}
        Err(e) => eprintln!("obs: dump failed: {e}"),
    }
    Ok(())
}
