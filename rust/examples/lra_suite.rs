//! LRA suite driver: Tables 1 & 2 plus the Figure-2/3 curves, over every
//! (task, attention) artifact that has been built.
//!
//! ```bash
//! # everything that `make artifacts` built, 200 steps, 1 seed:
//! cargo run --release --example lra_suite
//! # the full-grid reproduction (build with `make artifacts-full` first):
//! cargo run --release --example lra_suite -- --steps 600 --seeds 3 \
//!     --curves curves.json
//! ```
//!
//! Accuracy columns -> Table 1; s/step + peak memory -> Table 2; the
//! `--curves` JSON carries (wall-time, val-acc/val-loss) series -> Figures
//! 2 and 3.  Paper-vs-measured is recorded in EXPERIMENTS.md.

use skyformer::coordinator::trainer::{TrainConfig, Trainer};
use skyformer::report::tables::{fmt_bytes, fmt_secs, Table};
use skyformer::runtime::engine::Engine;
use skyformer::util::args::Args;
use skyformer::util::json;

fn main() -> skyformer::Result<()> {
    let args = Args::from_env();
    let engine = Engine::new(args.get_or("artifacts", "artifacts"))?;
    let steps = args.get_usize("steps", 200)?;
    let seeds = args.get_u64("seeds", 1)?;

    let mut configs = engine.manifest().trainable_configs();
    configs.retain(|(_, _, pallas)| !pallas);
    if let Some(only_tasks) = args.get_list("tasks") {
        configs.retain(|(t, _, _)| only_tasks.contains(t));
    }
    if let Some(only_attn) = args.get_list("attentions") {
        configs.retain(|(_, a, _)| only_attn.contains(a));
    }
    if configs.is_empty() {
        eprintln!("no artifacts match; run `make artifacts` (or artifacts-full)");
        return Ok(());
    }
    eprintln!("suite: {} configs x {seeds} seeds x {steps} steps", configs.len());

    let mut acc = Table::new(
        "Table 1: classification accuracy (%) on synthetic LRA",
        &["model", "task", "test_acc", "best_val", "seeds"],
    );
    let mut cost = Table::new(
        "Table 2: training cost",
        &["model", "task", "s/step", "total", "peak_mem"],
    );
    let mut curves = Vec::new();

    for (task, attn, _) in &configs {
        let mut test_accs = Vec::new();
        let mut best_accs = Vec::new();
        let mut step_secs = Vec::new();
        let mut totals = Vec::new();
        let mut peak = 0usize;
        for seed in 0..seeds {
            let mut cfg = TrainConfig::new(task, attn);
            cfg.steps = steps;
            cfg.eval_every = (steps / 6).max(10);
            cfg.eval_batches = args.get_usize("eval-batches", 8)?;
            cfg.seed = seed;
            let mut trainer = Trainer::new(&engine, cfg)?;
            let r = trainer.train()?;
            eprintln!(
                "{task}/{attn} seed {seed}: test {:.3} best {:.3} in {}",
                r.test_acc,
                r.best_eval_acc,
                fmt_secs(r.total_seconds)
            );
            test_accs.push(r.test_acc);
            best_accs.push(r.best_eval_acc);
            step_secs.push(r.metrics.mean_step_seconds());
            totals.push(r.total_seconds);
            peak = peak.max(r.metrics.peak_bytes);
            curves.push(json::obj(vec![
                ("task", json::s(task.clone())),
                ("attention", json::s(attn.clone())),
                ("seed", json::num(seed as f64)),
                ("metrics", r.metrics.to_json()),
            ]));
        }
        let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len() as f32;
        let meand = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        acc.row(vec![
            attn.clone(),
            task.clone(),
            format!("{:.2}", 100.0 * mean(&test_accs)),
            format!("{:.2}", 100.0 * mean(&best_accs)),
            seeds.to_string(),
        ]);
        cost.row(vec![
            attn.clone(),
            task.clone(),
            format!("{:.3}", meand(&step_secs)),
            fmt_secs(meand(&totals)),
            fmt_bytes(peak),
        ]);
    }

    println!("{}", acc.render());
    println!("{}", cost.render());
    if let Some(path) = args.get("curves") {
        std::fs::write(path, json::to_string(&json::Value::Array(curves)))?;
        println!("Figure 2/3 curves written to {path}");
    }
    Ok(())
}
