//! End-to-end training driver (the DESIGN.md §4 "E2E validation" run):
//! train the Skyformer LRA classifier on synthetic ListOps for a few
//! hundred steps, logging the loss curve, periodic validation accuracy,
//! and the final test accuracy of the best checkpoint.  Results are
//! recorded in EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example train_listops -- --steps 300 --attention skyformer
//! ```

use skyformer::coordinator::trainer::{TrainConfig, Trainer};
use skyformer::report::tables::{fmt_bytes, fmt_secs};
use skyformer::runtime::engine::Engine;
use skyformer::util::args::Args;

fn main() -> skyformer::Result<()> {
    let args = Args::from_env();
    skyformer::obs::init_from_env();
    if args.get("obs-out").is_some() {
        skyformer::obs::set_enabled(true);
    }
    let engine = Engine::new(args.get_or("artifacts", "artifacts"))?;

    let mut cfg = TrainConfig::new(
        args.get_or("task", "listops"),
        args.get_or("attention", "skyformer"),
    );
    cfg.steps = args.get_usize("steps", 300)?;
    cfg.eval_every = args.get_usize("eval-every", 50)?;
    cfg.eval_batches = args.get_usize("eval-batches", 8)?;
    cfg.seed = args.get_u64("seed", 0)?;
    cfg.verbose = true;
    cfg.log_every = 10;

    println!(
        "training {}/{} for {} steps (batch {}, seq {})",
        cfg.task,
        cfg.attention,
        cfg.steps,
        engine
            .manifest()
            .find(&cfg.task, &cfg.attention, "train", false)?
            .task_config
            .batch_size,
        engine
            .manifest()
            .find(&cfg.task, &cfg.attention, "train", false)?
            .task_config
            .seq_len,
    );

    let mut trainer = Trainer::new(&engine, cfg)?;
    let result = trainer.train()?;

    println!("\n=== loss curve (every 10 steps) ===");
    for rec in result.metrics.steps.iter().step_by(10) {
        println!(
            "step {:>5}  loss {:.4}  acc {:.3}  ({:.2}s/step)",
            rec.step, rec.loss, rec.acc, rec.wall_seconds
        );
    }
    println!("\n=== validation curve ===");
    for e in &result.metrics.evals {
        println!(
            "step {:>5}  val_loss {:.4}  val_acc {:.3}  @ {:.1}s",
            e.step, e.loss, e.acc, e.at_seconds
        );
    }
    println!("\n=== summary ===");
    println!("best val acc : {:.4}", result.best_eval_acc);
    println!("test acc     : {:.4}", result.test_acc);
    println!("total time   : {}", fmt_secs(result.total_seconds));
    println!("mean s/step  : {:.3}", result.metrics.mean_step_seconds());
    println!("peak tensors : {}", fmt_bytes(result.metrics.peak_bytes));

    if let Some(path) = args.get("metrics-out") {
        std::fs::write(
            path,
            skyformer::util::json::to_string(&result.metrics.to_json()),
        )?;
        println!("metrics json : {path}");
    }
    match skyformer::obs::finish(args.get("obs-out")) {
        Ok(paths) if !paths.is_empty() => eprintln!("obs: wrote {}", paths.join(", ")),
        Ok(_) => {}
        Err(e) => eprintln!("obs: dump failed: {e}"),
    }
    Ok(())
}
