//! Table-3 reproduction: instability-score ratios (paper Appendix F).
//!
//! Runs 20 update steps per model and reports
//! tau_i = ||f(x_i, W_i) - f(x_i, W_{i-1})||_F^2 / ||W_i - W_{i-1}||_F^2
//! as a per-step ratio against self-attention.  The paper's claim:
//! kernelized attention and Skyformer sit well below 1.0, Nyströmformer
//! hovers around 1.0.
//!
//! ```bash
//! cargo run --release --example instability -- --task listops
//! ```

use skyformer::coordinator::instability::InstabilityProbe;
use skyformer::coordinator::trainer::TrainConfig;
use skyformer::report::tables::Table;
use skyformer::runtime::engine::Engine;
use skyformer::util::args::Args;

fn main() -> skyformer::Result<()> {
    let args = Args::from_env();
    let engine = Engine::new(args.get_or("artifacts", "artifacts"))?;
    let task = args.get_or("task", "listops").to_string();
    let steps = args.get_usize("steps", 20)?;
    let lr = args.get_f32("lr", 1e-4)?;
    let seed = args.get_u64("seed", 0)?;
    let attentions = args.get_list("attentions").unwrap_or_else(|| {
        vec![
            "nystromformer".into(),
            "kernelized".into(),
            "skyformer".into(),
        ]
    });

    eprintln!("baseline: softmax self-attention ({steps} steps)");
    let mut cfg = TrainConfig::new(&task, "softmax");
    cfg.seed = seed;
    let mut probe = InstabilityProbe::new(&engine, cfg)?;
    let base = probe.run(steps, lr)?;

    let mut t = Table::new(
        &format!("Table 3: instability-score ratio vs self-attention ({task})"),
        &["model", "mean tau", "ratio (<1 = more stable)"],
    );
    t.row(vec![
        "self-attention".into(),
        format!("{:.4e}", base.mean_tau()),
        "1.00".into(),
    ]);

    for attn in &attentions {
        eprintln!("probing {attn} ...");
        let mut cfg = TrainConfig::new(&task, attn);
        cfg.seed = seed;
        let mut probe = match InstabilityProbe::new(&engine, cfg) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("  skip: {e}");
                continue;
            }
        };
        let r = probe.run(steps, lr)?;
        let ratio: f32 = r
            .taus
            .iter()
            .zip(&base.taus)
            .map(|(a, b)| a / b.max(1e-30))
            .sum::<f32>()
            / r.taus.len() as f32;
        t.row(vec![
            attn.clone(),
            format!("{:.4e}", r.mean_tau()),
            format!("{ratio:.2}"),
        ]);
    }
    println!("{}", t.render());
    println!("(paper Table 3, listops column: Nystromformer 1.01, KA 0.77, Skyformer 0.79)");
    Ok(())
}
