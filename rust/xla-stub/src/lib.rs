//! Offline stand-in for the `xla` (xla_extension 0.5.1) bindings.
//!
//! Mirrors exactly the API surface `skyformer::runtime` consumes, so the
//! `pjrt` feature type-checks without the PJRT shared library.  Host-side
//! `Literal` construction and round-tripping is fully functional; anything
//! that would need a real device client ([`PjRtClient::cpu`],
//! [`HloModuleProto::from_text_file`]) returns a descriptive [`Error`] at
//! runtime, which the coordinator surfaces as "artifacts unavailable" and
//! every bench/test skips gracefully.

use std::fmt;
use std::path::Path;

/// Stub error: carries the reason the PJRT path is unavailable.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT unavailable in this build (offline xla stub; \
         link the real xla_extension bindings to execute artifacts)"
    ))
}

/// Element types of the artifact signatures (subset of XLA's).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U8,
    U32,
    F32,
    F64,
}

impl ElementType {
    fn size_bytes(&self) -> usize {
        match self {
            ElementType::Pred | ElementType::U8 => 1,
            ElementType::S64 | ElementType::F64 => 8,
            ElementType::S32 | ElementType::U32 | ElementType::F32 => 4,
        }
    }
}

/// Rust scalar types that map onto an [`ElementType`].
pub trait NativeType: Copy {
    const TY: ElementType;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
}

impl NativeType for u32 {
    const TY: ElementType = ElementType::U32;
}

/// Array shape: element type + dimensions.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// XLA shape: an array or a tuple of shapes.
#[derive(Debug, Clone, PartialEq)]
pub enum Shape {
    Array(ArrayShape),
    Tuple(Vec<Shape>),
}

/// A host-resident literal (fully functional in the stub).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    shape: ArrayShape,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let n: usize = dims.iter().product();
        if n * ty.size_bytes() != data.len() {
            return Err(Error(format!(
                "literal: {n} x {ty:?} elements != {} bytes",
                data.len()
            )));
        }
        Ok(Literal {
            shape: ArrayShape {
                ty,
                dims: dims.iter().map(|&d| d as i64).collect(),
            },
            data: data.to_vec(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(self.shape.clone())
    }

    pub fn shape(&self) -> Result<Shape> {
        Ok(Shape::Array(self.shape.clone()))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.shape.ty != T::TY {
            return Err(Error(format!(
                "literal: cannot read {:?} data as {:?}",
                self.shape.ty,
                T::TY
            )));
        }
        let size = std::mem::size_of::<T>();
        let n = self.data.len() / size;
        let mut out: Vec<T> = Vec::with_capacity(n);
        // layout-compatible POD copy (little-endian host, as PJRT CPU uses)
        unsafe {
            std::ptr::copy_nonoverlapping(
                self.data.as_ptr(),
                out.as_mut_ptr() as *mut u8,
                n * size,
            );
            out.set_len(n);
        }
        Ok(out)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error("literal: not a tuple (offline xla stub)".into()))
    }
}

/// Device buffer handle (never constructible through the stub client).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("to_literal_sync"))
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("buffer_from_host_buffer"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compile"))
    }
}

/// Parsed HLO module (text form).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(Error(format!(
            "{}: cannot parse HLO text (offline xla stub)",
            path.as_ref().display()
        )))
    }
}

/// An XLA computation ready to compile.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute"))
    }

    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute_b"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data = [1.0f32, -2.5, 3.0];
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.array_shape().unwrap().dims(), &[3]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn size_mismatch_rejected() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0u8; 4]).is_err()
        );
    }

    #[test]
    fn client_is_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub"), "{err}");
    }
}
