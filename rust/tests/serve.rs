//! End-to-end tests of the serving subsystem through its public API:
//! admission, micro-batching, deadline shedding, graceful drain — and
//! the load-bearing guarantee that batched dispatch is **bit-identical**
//! to per-request dispatch, for every schedule (thread count × pool
//! mode) and any batch composition the timing happens to produce.
//! That guarantee is what makes the timing-dependent micro-batcher safe
//! to put in front of deterministic kernels (SERVING.md).

use std::time::{Duration, Instant};

use skyformer::attention::exact;
use skyformer::kernels::{self, pool, KernelCtx};
use skyformer::linalg::Matrix;
use skyformer::serve::{
    Head, ModelKind, Outcome, Priority, RejectReason, Request, ServeConfig, Server, ShedReason,
};
use skyformer::util::rng::Rng;

/// A request derived purely from `(seed, id)` — resubmittable and
/// recomputable without coordination.
fn gen_request(
    seed: u64,
    id: u64,
    kind: ModelKind,
    (n, m, p, dv): (usize, usize, usize, usize),
    heads: usize,
) -> Request {
    let root = Rng::new(seed).split(id);
    let heads = (0..heads)
        .map(|h| {
            let mut r = root.split(h as u64 + 1);
            Head {
                q: Matrix::randn(&mut r, n, p, 0.5),
                k: Matrix::randn(&mut r, m, p, 0.5),
                v: Matrix::randn(&mut r, m, dv, 1.0),
            }
        })
        .collect();
    Request { id, kind, heads, deadline: None, priority: Priority::Normal }
}

/// Per-request (unbatched) reference outputs under a fixed 1-thread
/// scoped schedule — the oracle every served output must equal bitwise.
fn reference_outputs(req: &Request) -> Vec<Matrix> {
    let ctx = KernelCtx::with_threads(1).with_mode(pool::Mode::Scoped);
    req.heads
        .iter()
        .map(|h| match req.kind {
            ModelKind::Exact => exact::softmax_attention_in(ctx, &h.q, &h.k, &h.v),
            ModelKind::Kernelized => exact::kernelized_attention_in(ctx, &h.q, &h.k, &h.v),
        })
        .collect()
}

fn assert_bitwise_eq(got: &[Matrix], want: &[Matrix], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: head count");
    for (g, w) in got.iter().zip(want) {
        assert_eq!(kernels::digest(g), kernels::digest(w), "{what}: outputs differ bitwise");
    }
}

/// The shape/kind mix used by the end-to-end tests: two bucket shapes ×
/// two model kinds × varying head counts, so batching has real
/// coalescing decisions to make.
fn mixed_request(seed: u64, id: u64) -> Request {
    let kind = if id % 2 == 0 { ModelKind::Exact } else { ModelKind::Kernelized };
    let shape = if id % 3 == 0 { (12, 10, 5, 4) } else { (8, 8, 4, 4) };
    gen_request(seed, id, kind, shape, 1 + (id as usize % 3))
}

#[test]
fn served_outputs_bit_identical_to_unbatched_across_schedules() {
    for mode in [pool::Mode::Scoped, pool::Mode::Pinned] {
        for threads in [1usize, 4] {
            let ctx = KernelCtx::with_threads(threads).with_mode(mode);
            let cfg = ServeConfig {
                queue_capacity: 64,
                max_batch: 4,
                max_wait: Duration::from_millis(2),
                ..ServeConfig::default()
            };
            let server = Server::start(cfg, ctx);
            let requests: Vec<Request> = (0..16).map(|id| mixed_request(7, id)).collect();
            let tickets: Vec<_> = requests
                .iter()
                .map(|r| server.submit(r.clone()).expect("admission"))
                .collect();
            for (req, ticket) in requests.iter().zip(&tickets) {
                match ticket.wait() {
                    Outcome::Completed { outputs } => assert_bitwise_eq(
                        &outputs,
                        &reference_outputs(req),
                        &format!("req {} ({mode:?} x{threads})", req.id),
                    ),
                    other => panic!("req {} did not complete: {other:?}", req.id),
                }
            }
            server.shutdown();
        }
    }
}

/// Regression for the gather-loop livelock: the leader's bucket holds
/// fewer requests than `max_batch` while the queue holds only requests
/// of *another* bucket.  The dispatcher must dispatch the partial batch
/// once `max_wait` elapses (and then serve the other bucket), rather
/// than spinning on the incompatible backlog forever.
#[test]
fn partial_batch_dispatches_despite_foreign_bucket_backlog() {
    let ctx = KernelCtx::with_threads(1).with_mode(pool::Mode::Scoped);
    let cfg = ServeConfig {
        queue_capacity: 64,
        max_batch: 4,
        max_wait: Duration::from_millis(2),
        ..ServeConfig::default()
    };
    let server = Server::start(cfg, ctx);
    // 3 requests of one bucket (can never reach max_batch = 4) and 2 of
    // another, admitted back-to-back so they queue together
    let requests: Vec<Request> = (0..3u64)
        .map(|id| gen_request(21, id, ModelKind::Exact, (8, 8, 4, 4), 1))
        .chain((3..5u64).map(|id| gen_request(21, id, ModelKind::Kernelized, (12, 10, 5, 4), 2)))
        .collect();
    let tickets: Vec<_> = requests
        .iter()
        .map(|r| server.submit(r.clone()).expect("admission"))
        .collect();
    for (req, ticket) in requests.iter().zip(&tickets) {
        match ticket.wait() {
            Outcome::Completed { outputs } => assert_bitwise_eq(
                &outputs,
                &reference_outputs(req),
                &format!("req {}", req.id),
            ),
            other => panic!("req {} did not complete: {other:?}", req.id),
        }
    }
    server.shutdown();
}

#[test]
fn shutdown_drains_already_admitted_requests() {
    let ctx = KernelCtx::with_threads(2).with_mode(pool::Mode::Scoped);
    let cfg = ServeConfig {
        queue_capacity: 64,
        max_batch: 8,
        max_wait: Duration::from_micros(100),
        ..ServeConfig::default()
    };
    let server = Server::start(cfg, ctx);
    let tickets: Vec<_> = (0..12)
        .map(|id| server.submit(mixed_request(11, id)).expect("admission"))
        .collect();
    // shutdown before waiting on anything: drain must complete them all
    server.shutdown();
    for (id, t) in tickets.iter().enumerate() {
        assert!(
            matches!(t.wait(), Outcome::Completed { .. }),
            "request {id} not completed by the drain"
        );
    }
}

#[test]
fn expired_requests_are_shed_not_served() {
    let ctx = KernelCtx::with_threads(1).with_mode(pool::Mode::Scoped);
    let server = Server::start(ServeConfig::default(), ctx);
    let mut req = mixed_request(13, 0);
    req.deadline = Some(Instant::now() - Duration::from_millis(1));
    let dead = server.submit(req).expect("expired requests are admitted, shed later");
    let live = server.submit(mixed_request(13, 1)).expect("admission");
    assert!(matches!(dead.wait(), Outcome::Shed(ShedReason::DeadlineExpired)));
    assert!(matches!(live.wait(), Outcome::Completed { .. }));
    server.shutdown();
}

#[test]
fn malformed_requests_never_enter_the_queue() {
    let ctx = KernelCtx::with_threads(1).with_mode(pool::Mode::Scoped);
    let server = Server::start(ServeConfig::default(), ctx);
    let no_heads = Request {
        id: 0,
        kind: ModelKind::Exact,
        heads: vec![],
        deadline: None,
        priority: Priority::Normal,
    };
    assert!(matches!(server.submit(no_heads), Err(RejectReason::Malformed(_))));
    let mut mixed_shapes = mixed_request(17, 0);
    mixed_shapes.heads = vec![
        gen_request(17, 1, mixed_shapes.kind, (8, 8, 4, 4), 1).heads.remove(0),
        gen_request(17, 2, mixed_shapes.kind, (9, 8, 4, 4), 1).heads.remove(0),
    ];
    assert!(matches!(server.submit(mixed_shapes), Err(RejectReason::Malformed(_))));
    server.shutdown();
}

/// Sharding must change scheduling only, never bytes: the same request
/// set served through 1 and through 4 dispatcher shards completes with
/// identical (reference-equal) outputs.
#[test]
fn sharded_server_outputs_bit_identical_to_single_dispatcher() {
    let requests: Vec<Request> = (0..20).map(|id| mixed_request(29, id)).collect();
    for dispatchers in [1usize, 4] {
        let ctx = KernelCtx::with_threads(2).with_mode(pool::Mode::Scoped);
        let cfg = ServeConfig {
            queue_capacity: 64,
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            dispatchers,
            ..ServeConfig::default()
        };
        let server = Server::start(cfg, ctx);
        let tickets: Vec<_> = requests
            .iter()
            .map(|r| server.submit(r.clone()).expect("admission"))
            .collect();
        for (req, ticket) in requests.iter().zip(&tickets) {
            match ticket.wait() {
                Outcome::Completed { outputs } => assert_bitwise_eq(
                    &outputs,
                    &reference_outputs(req),
                    &format!("req {} (dispatchers={dispatchers})", req.id),
                ),
                other => panic!(
                    "req {} did not complete under {dispatchers} dispatchers: {other:?}",
                    req.id
                ),
            }
        }
        server.shutdown();
    }
}

/// Priority lanes end to end: a mixed High/Normal load where every
/// request still completes with reference-equal bytes — the lanes
/// reorder batch formation, never outputs — and High requests are
/// admitted and served like any other.
#[test]
fn priority_lanes_change_scheduling_not_bytes() {
    let ctx = KernelCtx::with_threads(2).with_mode(pool::Mode::Pinned);
    let cfg = ServeConfig {
        queue_capacity: 64,
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        dispatchers: 2,
        ..ServeConfig::default()
    };
    let server = Server::start(cfg, ctx);
    let requests: Vec<Request> = (0..18)
        .map(|id| {
            let mut req = mixed_request(31, id);
            if id % 3 == 0 {
                req.priority = Priority::High;
            }
            req
        })
        .collect();
    let tickets: Vec<_> = requests
        .iter()
        .map(|r| server.submit(r.clone()).expect("admission"))
        .collect();
    for (req, ticket) in requests.iter().zip(&tickets) {
        match ticket.wait() {
            Outcome::Completed { outputs } => assert_bitwise_eq(
                &outputs,
                &reference_outputs(req),
                &format!("req {} ({})", req.id, req.priority.name()),
            ),
            other => panic!("req {} did not complete: {other:?}", req.id),
        }
    }
    server.shutdown();
}

/// `close()` is the non-blocking half of shutdown: new submissions are
/// rejected immediately with ShuttingDown, but the already-admitted
/// backlog still drains to completion when shutdown() follows.
#[test]
fn close_rejects_new_submits_but_drains_admitted() {
    let ctx = KernelCtx::with_threads(1).with_mode(pool::Mode::Scoped);
    let server = Server::start(ServeConfig::default(), ctx);
    let tickets: Vec<_> = (0..6)
        .map(|id| server.submit(mixed_request(37, id)).expect("admission"))
        .collect();
    server.close();
    assert!(matches!(
        server.submit(mixed_request(37, 100)),
        Err(RejectReason::ShuttingDown)
    ));
    server.close(); // idempotent
    server.shutdown();
    for (id, t) in tickets.iter().enumerate() {
        assert!(
            matches!(t.wait(), Outcome::Completed { .. }),
            "request {id} not completed by the post-close drain"
        );
    }
}

/// Property sweep: random request mixes and serving knobs — every
/// accepted request completes with bitwise-reference outputs, whatever
/// batches the timing produced.
#[test]
fn prop_any_batching_schedule_preserves_outputs() {
    for case in 0..6u64 {
        let mut rng = Rng::new(case);
        let threads = 1 + rng.below(4);
        let mode = if rng.below(2) == 0 { pool::Mode::Scoped } else { pool::Mode::Pinned };
        let cfg = ServeConfig {
            queue_capacity: 64,
            max_batch: 1 + rng.below(6),
            max_wait: Duration::from_micros(50 + rng.below(2000) as u64),
            dispatchers: 1 + rng.below(4),
            ..ServeConfig::default()
        };
        let ctx = KernelCtx::with_threads(threads).with_mode(mode);
        let server = Server::start(cfg, ctx);
        let n_req = 4 + rng.below(12) as u64;
        let requests: Vec<Request> = (0..n_req)
            .map(|id| {
                let mut req = mixed_request(100 + case, id);
                if rng.below(3) == 0 {
                    req.priority = Priority::High;
                }
                req
            })
            .collect();
        let tickets: Vec<_> = requests
            .iter()
            .map(|r| server.submit(r.clone()).expect("admission"))
            .collect();
        for (req, ticket) in requests.iter().zip(&tickets) {
            match ticket.wait() {
                Outcome::Completed { outputs } => assert_bitwise_eq(
                    &outputs,
                    &reference_outputs(req),
                    &format!("case {case} req {}", req.id),
                ),
                other => panic!("case {case} req {} did not complete: {other:?}", req.id),
            }
        }
        server.shutdown();
    }
}
