//! Integration tests for the observability layer: spans recorded through
//! the public API, Chrome-trace/JSONL serialization round-trips via
//! `util::json`, and the metrics export formats.

use skyformer::obs::{self, export, metrics};
use skyformer::util::json;

/// All tests in this file toggle the process-wide tracing flag, so they
/// serialise on the span test lock and use unique category names.
fn lock() -> std::sync::MutexGuard<'static, ()> {
    obs::span::test_lock().lock().unwrap_or_else(|p| p.into_inner())
}

fn events_in(cat: &'static str) -> Vec<obs::TraceEvent> {
    obs::snapshot_events()
        .into_iter()
        .filter(|e| e.cat == cat)
        .collect()
}

#[test]
fn nested_spans_roundtrip_through_chrome_trace() {
    let _g = lock();
    obs::set_enabled(true);
    {
        let _outer = obs::span("it_nest", "outer");
        std::thread::sleep(std::time::Duration::from_millis(2));
        {
            let _inner = obs::span("it_nest", "inner");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
    }
    let evs = events_in("it_nest");
    let text = json::to_string(&export::chrome_trace(&evs));
    let doc = json::parse(&text).unwrap();
    let arr = doc.get("traceEvents").unwrap().as_array().unwrap();
    assert_eq!(arr.len(), 2);

    let find = |name: &str| {
        arr.iter()
            .find(|e| e.get("name").unwrap().as_str() == Some(name))
            .unwrap()
    };
    let (outer, inner) = (find("outer"), find("inner"));
    for e in [outer, inner] {
        assert_eq!(e.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(e.get("pid").unwrap().as_f64(), Some(1.0));
        assert!(e.get("ts").unwrap().as_f64().is_some());
        assert!(e.get("dur").unwrap().as_f64().unwrap() > 0.0);
    }
    // chrome://tracing infers nesting from containment — verify it holds
    let ots = outer.get("ts").unwrap().as_f64().unwrap();
    let odur = outer.get("dur").unwrap().as_f64().unwrap();
    let its = inner.get("ts").unwrap().as_f64().unwrap();
    let idur = inner.get("dur").unwrap().as_f64().unwrap();
    assert!(its >= ots && its + idur <= ots + odur);
    assert_eq!(
        outer.get("tid").unwrap().as_f64(),
        inner.get("tid").unwrap().as_f64()
    );
}

#[test]
fn jsonl_lines_parse_independently() {
    let _g = lock();
    obs::set_enabled(true);
    obs::event(
        "it_jsonl",
        "mark \"quoted\"\nnewline",
        Some(json::obj(vec![("k", json::s("v"))])),
    );
    {
        let _s = obs::span("it_jsonl", "work");
    }
    let evs = events_in("it_jsonl");
    let text = export::to_jsonl(&evs);
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 2);
    for line in &lines {
        let v = json::parse(line).unwrap();
        assert_eq!(v.get("cat").unwrap().as_str(), Some("it_jsonl"));
    }
    // the quoted/newlined name survived the escape round-trip
    let first = json::parse(lines[0]).unwrap();
    assert_eq!(
        first.get("name").unwrap().as_str(),
        Some("mark \"quoted\"\nnewline")
    );
}

#[test]
fn metrics_snapshot_exports_both_formats() {
    let _g = lock();
    metrics::counter_add("it_obs_steps_total", 4);
    metrics::observe("it_obs_step_seconds", 0.012);
    metrics::observe("it_obs_step_seconds", 0.015);
    let snap = metrics::snapshot();

    let v = snap.to_json();
    let back = json::parse(&json::to_string(&v)).unwrap();
    assert_eq!(
        back.get("counters")
            .unwrap()
            .get("it_obs_steps_total")
            .unwrap()
            .as_f64(),
        Some(4.0)
    );
    let h = back
        .get("histograms")
        .unwrap()
        .get("it_obs_step_seconds")
        .unwrap();
    assert_eq!(h.get("count").unwrap().as_f64(), Some(2.0));

    let prom = snap.to_prometheus();
    assert!(prom.contains("# TYPE it_obs_step_seconds histogram"), "{prom}");
    assert!(prom.contains("it_obs_step_seconds_bucket{le=\"+Inf\"} 2"), "{prom}");
    assert!(prom.contains("it_obs_steps_total 4"), "{prom}");
}

#[test]
fn ns_inverse_emits_convergence_trail_when_enabled() {
    let _g = lock();
    obs::set_enabled(true);
    let before = events_in("nystrom").len();
    let mut rng = skyformer::util::rng::Rng::new(3);
    let x = skyformer::linalg::Matrix::randn(&mut rng, 24, 6, 0.5);
    let gram = skyformer::nystrom::kernel_matrix(skyformer::nystrom::Kernel::Gaussian, &x, &x);
    let _ = skyformer::linalg::solve::ns_inverse(&gram, 1e-3, 8);
    let evs = events_in("nystrom");
    let iters: Vec<_> = evs[before..]
        .iter()
        .filter(|e| e.name == "ns_iter")
        .collect();
    assert_eq!(iters.len(), 8);
    // residuals decrease over the iteration (convergent input)
    let res = |e: &&obs::TraceEvent| {
        e.args
            .as_ref()
            .unwrap()
            .get("residual")
            .unwrap()
            .as_f64()
            .unwrap()
    };
    assert!(res(&iters[7]) < res(&iters[0]), "no convergence trail");
    // per-iteration residuals also land in the histogram
    match metrics::snapshot().metrics.get("ns_iter_residual") {
        Some(metrics::Metric::Histogram(h)) => assert!(h.count >= 8),
        other => panic!("expected ns_iter_residual histogram, got {other:?}"),
    }
}

#[test]
fn dump_prefix_writes_consistent_fileset() {
    let _g = lock();
    obs::set_enabled(true);
    {
        let _s = obs::span("it_dump", "scope");
    }
    metrics::gauge_set("it_dump_gauge", 2.5);
    let dir = std::env::temp_dir().join("skyformer_obs_it_dump");
    let prefix = dir.join("run").to_string_lossy().into_owned();
    let paths = obs::dump(&prefix).unwrap();
    assert_eq!(paths.len(), 4);
    let trace = std::fs::read_to_string(&paths[0]).unwrap();
    let doc = json::parse(&trace).unwrap();
    assert!(doc
        .get("traceEvents")
        .unwrap()
        .as_array()
        .unwrap()
        .iter()
        .any(|e| e.get("cat").unwrap().as_str() == Some("it_dump")));
    let prom = std::fs::read_to_string(&paths[3]).unwrap();
    assert!(prom.contains("it_dump_gauge 2.5"), "{prom}");
    let _ = std::fs::remove_dir_all(&dir);
}
