//! Golden-digest regression gates for the kernel subsystem.
//!
//! `skyformer kernels --digest` and these tests share the workload
//! factories (`kernels::digest_suite`, `kernels::digest_suite_portable`),
//! so the committed fixtures can never drift from what the binary
//! prints.  Two fixtures, two trust models:
//!
//! * **`tests/golden/kernels.portable.digest`** — the portable suite:
//!   kernels whose data path is pure IEEE-754 f32 `+`/`*` in the
//!   contract's fixed reduction orders, on `Uniform[-1,1)` inputs whose
//!   generation is pure bit manipulation.  Those digests are identical
//!   on every IEEE platform, so the fixture can be generated off-host
//!   (`scripts/seed_golden_portable.py`) and enforced everywhere.  The
//!   fixture carries a `# seeded-by:` provenance header: `host` (seeded
//!   by this test on a toolchain host) is hard-asserted; `emulation`
//!   (seeded by the numpy script) is warn-only under plain `cargo test`
//!   — `scripts/ci.sh` hard-fails on any portable mismatch regardless,
//!   so the drift gate is enforced in CI either way.
//! * **`tests/golden/kernels.digest`** — the full suite.  Its digests
//!   pass through `exp()` and are therefore pinned to the platform's
//!   libm: on a fresh platform (fixture still UNSEEDED) the drift check
//!   is skipped with a loud warning, and seeding is explicit
//!   (`SKYFORMER_GOLDEN_SEED=1 cargo test --test golden`, then commit;
//!   see KERNELS.md, "Golden digest fixture").
//!
//! Both tests always enforce **cross-schedule determinism**: digest
//! lines byte-equal across thread counts {1, 4, 8} × pool modes
//! {scoped, pinned}, on any platform, seeded or not.

use skyformer::kernels::{self, pool, KernelCtx};
use skyformer::linalg::Matrix;

const FIXTURE: &str = include_str!("golden/kernels.digest");
const FIXTURE_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden/kernels.digest");
const PORTABLE_FIXTURE: &str = include_str!("golden/kernels.portable.digest");
const PORTABLE_FIXTURE_PATH: &str =
    concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden/kernels.portable.digest");

/// Digest lines for one schedule, with oracle parity asserted on the way
/// — the exact stdout of `skyformer kernels --digest [--suite ...]`.
fn digest_lines(
    suite: impl Fn(KernelCtx) -> Vec<(&'static str, Matrix, Matrix)>,
    threads: usize,
    mode: pool::Mode,
) -> String {
    let ctx = KernelCtx::with_threads(threads).with_mode(mode);
    let mut out = String::new();
    for (name, m, reference) in suite(ctx) {
        assert_eq!(
            kernels::digest(&m),
            kernels::digest(&reference),
            "{name} diverged from its scalar oracle ({mode:?}, {threads} threads)"
        );
        out.push_str(&format!("{name} {:016x}\n", kernels::digest(&m)));
    }
    out
}

/// Assert one suite's lines are byte-equal across the schedule grid and
/// return the canonical lines.
fn cross_schedule_lines(
    suite: impl Fn(KernelCtx) -> Vec<(&'static str, Matrix, Matrix)> + Copy,
) -> String {
    let base = digest_lines(suite, 1, pool::Mode::Scoped);
    for mode in [pool::Mode::Scoped, pool::Mode::Pinned] {
        for threads in [1usize, 4, 8] {
            assert_eq!(
                digest_lines(suite, threads, mode),
                base,
                "digest diverged at {mode:?} x {threads} threads"
            );
        }
    }
    base
}

fn seeding_requested() -> bool {
    std::env::var("SKYFORMER_GOLDEN_SEED").as_deref() == Ok("1")
}

/// Fixture body with `#` comment lines (provenance header) stripped.
fn fixture_body(text: &str) -> String {
    text.lines()
        .filter(|l| !l.starts_with('#'))
        .fold(String::new(), |mut s, l| {
            s.push_str(l);
            s.push('\n');
            s
        })
}

#[test]
fn kernel_digests_stable_across_schedules_and_match_golden_fixture() {
    let base = cross_schedule_lines(|ctx| kernels::digest_suite(ctx, 96, 16, 42));

    if FIXTURE.starts_with("UNSEEDED") {
        // Never self-seed implicitly: a plain `cargo test` must not
        // write into the source tree (it would panic on a read-only
        // checkout, and a silent in-place seed lets the drift gate go
        // unenforced forever if the file is never committed).  Seeding
        // is an explicit operator action; `scripts/ci.sh` hard-fails on
        // an UNSEEDED fixture, so CI cannot pass with the drift gate
        // off.  Cross-schedule determinism (above) is asserted either
        // way.
        if seeding_requested() {
            std::fs::write(FIXTURE_PATH, &base).expect("seed golden fixture");
            eprintln!("golden: seeded {FIXTURE_PATH}; commit the regenerated file");
        } else {
            eprintln!(
                "golden: WARNING: {FIXTURE_PATH} is UNSEEDED — numeric drift is NOT \
                 being checked (cross-schedule determinism was).  Seed it with \
                 `SKYFORMER_GOLDEN_SEED=1 cargo test --test golden` and commit the \
                 regenerated file (see KERNELS.md, \"Golden digest fixture\"); \
                 scripts/ci.sh refuses to pass until then."
            );
        }
        return;
    }
    assert_eq!(
        base, FIXTURE,
        "live kernel digests diverged from tests/golden/kernels.digest; \
         if the numeric change is intended, regenerate the fixture per KERNELS.md"
    );
}

#[test]
fn portable_digests_stable_across_schedules_and_match_fixture() {
    let base = cross_schedule_lines(|ctx| kernels::digest_suite_portable(ctx, 96, 42));

    if seeding_requested() {
        // a host-seeded portable fixture supersedes the emulation one:
        // upgrade the provenance header so the hard assert arms itself
        let body = format!("# seeded-by: host (SKYFORMER_GOLDEN_SEED=1)\n{base}");
        std::fs::write(PORTABLE_FIXTURE_PATH, body).expect("seed portable golden fixture");
        eprintln!("golden: seeded {PORTABLE_FIXTURE_PATH}; commit the regenerated file");
        return;
    }

    let want = fixture_body(PORTABLE_FIXTURE);
    let host_seeded = PORTABLE_FIXTURE
        .lines()
        .next()
        .is_some_and(|l| l.starts_with("# seeded-by: host"));
    if base == want {
        return;
    }
    if host_seeded {
        panic!(
            "live portable digests diverged from tests/golden/kernels.portable.digest \
             (host-seeded); if the numeric change is intended, regenerate per KERNELS.md.\n\
             live:\n{base}\nfixture:\n{want}"
        );
    }
    // emulation-seeded (or headerless): the fixture was produced off-host
    // by scripts/seed_golden_portable.py.  A mismatch here most likely
    // means real kernel drift — but the conservative reading is an
    // emulation bug, so plain `cargo test` warns instead of failing;
    // scripts/ci.sh diffs the same lines and hard-fails.
    eprintln!(
        "golden: WARNING: portable digests do not match the emulation-seeded fixture \
         {PORTABLE_FIXTURE_PATH}.\nlive:\n{base}\nfixture:\n{want}\n\
         Either kernel arithmetic drifted or the off-host emulation is wrong; \
         scripts/ci.sh fails on this.  Reseed on this host with \
         `SKYFORMER_GOLDEN_SEED=1 cargo test --test golden` and commit."
    );
}
