//! Golden-digest regression gate for the kernel subsystem.
//!
//! `skyformer kernels --digest` and this test share one workload factory
//! (`kernels::digest_suite`), so the committed fixture
//! `tests/golden/kernels.digest` can never drift from what the binary
//! prints.  The test enforces two distinct properties:
//!
//! 1. **Cross-schedule determinism** — the digest lines are byte-equal
//!    across thread counts {1, 4, 8} × pool modes {scoped, pinned}
//!    (always enforced, on any platform).
//! 2. **Numeric drift** — the lines match the committed fixture, so an
//!    unintended change to any kernel's arithmetic fails tests even when
//!    it is internally consistent across schedules.  Digests pass
//!    through `exp()`, so the fixture is pinned to the CI platform's
//!    libm: on a fresh platform (fixture still UNSEEDED) the drift
//!    check is skipped with a loud warning — the test never writes the
//!    source tree on its own.  Seeding is explicit
//!    (`SKYFORMER_GOLDEN_SEED=1 cargo test --test golden`, then commit
//!    the file; see KERNELS.md, "Golden digest fixture"), and
//!    `scripts/ci.sh` hard-fails on an UNSEEDED fixture so CI can never
//!    pass with the drift gate unenforced.

use skyformer::kernels::{self, pool, KernelCtx};

const FIXTURE: &str = include_str!("golden/kernels.digest");
const FIXTURE_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden/kernels.digest");

/// The exact stdout of `skyformer kernels --digest` for one schedule
/// (default n=96 p=16 seed=42), with oracle parity asserted on the way.
fn digest_lines(threads: usize, mode: pool::Mode) -> String {
    let ctx = KernelCtx::with_threads(threads).with_mode(mode);
    let mut out = String::new();
    for (name, m, reference) in kernels::digest_suite(ctx, 96, 16, 42) {
        assert_eq!(
            kernels::digest(&m),
            kernels::digest(&reference),
            "{name} diverged from its scalar oracle ({mode:?}, {threads} threads)"
        );
        out.push_str(&format!("{name} {:016x}\n", kernels::digest(&m)));
    }
    out
}

#[test]
fn kernel_digests_stable_across_schedules_and_match_golden_fixture() {
    let base = digest_lines(1, pool::Mode::Scoped);
    for mode in [pool::Mode::Scoped, pool::Mode::Pinned] {
        for threads in [1usize, 4, 8] {
            assert_eq!(
                digest_lines(threads, mode),
                base,
                "digest diverged at {mode:?} x {threads} threads"
            );
        }
    }

    if FIXTURE.starts_with("UNSEEDED") {
        // Never self-seed implicitly: a plain `cargo test` must not
        // write into the source tree (it would panic on a read-only
        // checkout, and a silent in-place seed lets the drift gate go
        // unenforced forever if the file is never committed).  Seeding
        // is an explicit operator action; `scripts/ci.sh` hard-fails on
        // an UNSEEDED fixture, so CI cannot pass with the drift gate
        // off.  Cross-schedule determinism (above) is asserted either
        // way.
        if std::env::var("SKYFORMER_GOLDEN_SEED").as_deref() == Ok("1") {
            std::fs::write(FIXTURE_PATH, &base).expect("seed golden fixture");
            eprintln!("golden: seeded {FIXTURE_PATH}; commit the regenerated file");
        } else {
            eprintln!(
                "golden: WARNING: {FIXTURE_PATH} is UNSEEDED — numeric drift is NOT \
                 being checked (cross-schedule determinism was).  Seed it with \
                 `SKYFORMER_GOLDEN_SEED=1 cargo test --test golden` and commit the \
                 regenerated file (see KERNELS.md, \"Golden digest fixture\"); \
                 scripts/ci.sh refuses to pass until then."
            );
        }
        return;
    }
    assert_eq!(
        base, FIXTURE,
        "live kernel digests diverged from tests/golden/kernels.digest; \
         if the numeric change is intended, regenerate the fixture per KERNELS.md"
    );
}
