//! Concurrency stress / fault-injection suite for the serving
//! subsystem: many client threads × mixed buckets × mixed priority
//! lanes × random deadlines, racing a mid-load `Server::close()` —
//! the accounting invariant under fire.
//!
//! Invariants asserted every iteration:
//!
//! * **Zero lost tickets**: completed + shed + rejected == submitted.
//!   Every submission either returns an admission error (rejected) or a
//!   ticket, and every ticket resolves — no `Ticket::wait()` deadlocks,
//!   even with shutdown racing admission.
//! * **No Dropped outcomes**: close() + shutdown() is the *graceful*
//!   path; the teardown safety-net (`ShedReason::Dropped`) must never
//!   fire on it.
//! * **Bytes under fire**: every completed output is bit-identical to
//!   an unbatched recompute on a fixed 1-thread scoped schedule —
//!   whatever batches, shards, lanes, and shed decisions the race
//!   produced.
//!
//! Both pool backends run the same gauntlet.  Iteration count is
//! `SKYFORMER_STRESS_ITERS` (default 3; scripts/ci.sh runs 10; the PR
//! acceptance bar is 50 clean consecutive iterations).

use std::time::{Duration, Instant};

use skyformer::attention::exact;
use skyformer::kernels::{self, pool, KernelCtx};
use skyformer::linalg::Matrix;
use skyformer::serve::{
    Head, ModelKind, Outcome, Priority, Request, ServeConfig, Server, ShedReason, Ticket,
};
use skyformer::util::rng::Rng;

const CLIENTS: usize = 16;
const PER_CLIENT: usize = 24;

/// Request data, lane, and deadline *class* are all pure functions of
/// `(seed, id)` — any completed request can be regenerated for the
/// unbatched recompute, and reruns of a failing iteration see the same
/// workload (modulo wall-clock deadline races, which only move requests
/// between the completed and shed buckets — both legal).
fn gen_request(seed: u64, id: u64) -> Request {
    let mut r = Rng::new(seed).split(id);
    let kind = if r.below(2) == 0 { ModelKind::Exact } else { ModelKind::Kernelized };
    let (n, m, p, dv) = [(8, 8, 4, 4), (12, 10, 5, 4), (6, 8, 4, 2)][r.below(3)];
    let heads = (0..1 + r.below(3))
        .map(|h| {
            let mut hr = Rng::new(seed).split(id).split(h as u64 + 1);
            Head {
                q: Matrix::randn(&mut hr, n, p, 0.5),
                k: Matrix::randn(&mut hr, m, p, 0.5),
                v: Matrix::randn(&mut hr, m, dv, 1.0),
            }
        })
        .collect();
    let priority = if r.below(3) == 0 { Priority::High } else { Priority::Normal };
    // deadline classes: most never expire; some are dead on arrival
    // (must shed); some are tight enough to race the pipeline either way
    let deadline = match r.below(8) {
        0 => Some(Instant::now() - Duration::from_millis(1)),
        1 => Some(Instant::now() + Duration::from_micros(200 + r.below(3000) as u64)),
        _ => None,
    };
    Request { id, kind, heads, deadline, priority }
}

/// Unbatched per-request oracle on a fixed schedule.
fn reference_digest(seed: u64, id: u64) -> u64 {
    let ctx = KernelCtx::with_threads(1).with_mode(pool::Mode::Scoped);
    let req = gen_request(seed, id);
    const FNV: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    req.heads.iter().fold(FNV, |h, hd| {
        let out = match req.kind {
            ModelKind::Exact => exact::softmax_attention_in(ctx, &hd.q, &hd.k, &hd.v),
            ModelKind::Kernelized => exact::kernelized_attention_in(ctx, &hd.q, &hd.k, &hd.v),
        };
        (h ^ kernels::digest(&out)).wrapping_mul(FNV_PRIME)
    })
}

fn served_digest(outputs: &[Matrix]) -> u64 {
    const FNV: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    outputs.iter().fold(FNV, |h, o| (h ^ kernels::digest(o)).wrapping_mul(FNV_PRIME))
}

/// One full gauntlet: spin up a server, race 16 clients against a
/// mid-load close(), drain, and audit the books.
fn stress_once(iter: u64, mode: pool::Mode) {
    let seed = 0xC0FFEE + iter;
    let ctx = KernelCtx::with_threads(2 + (iter % 3) as usize).with_mode(mode);
    let cfg = ServeConfig {
        // small shards: real backpressure (QueueFull) under 16 clients
        queue_capacity: 8,
        max_batch: 3,
        max_wait: Duration::from_micros(200),
        dispatchers: 1 + (iter % 4) as usize,
        ..ServeConfig::default()
    };
    let server = Server::start(cfg, ctx);

    // (id, Some(ticket) | None = rejected at admission)
    let results: Vec<(u64, Option<Ticket>)> = std::thread::scope(|scope| {
        let server = &server;
        let handles: Vec<_> = (0..CLIENTS)
            .map(|c| {
                scope.spawn(move || {
                    let mut out = Vec::with_capacity(PER_CLIENT);
                    for j in 0..PER_CLIENT {
                        let id = (c * 1000 + j) as u64;
                        let req = gen_request(seed, id);
                        // no retry: a rejection (QueueFull from the tiny
                        // shards, ShuttingDown from the racer) is a
                        // legal terminal state the audit must count
                        out.push((id, server.submit(req).ok()));
                    }
                    out
                })
            })
            .collect();
        // fault injection: close admission somewhere in the middle of
        // the submission storm — every in-flight submit must land in
        // exactly one bucket (ticket or rejection), never vanish
        let racer = scope.spawn(move || {
            std::thread::sleep(Duration::from_micros(300 + (seed % 700)));
            server.close();
            server.close(); // idempotent under the race too
        });
        racer.join().expect("close racer");
        handles.into_iter().flat_map(|h| h.join().expect("client thread")).collect()
    });
    server.shutdown();

    let submitted = results.len();
    let (mut completed, mut shed, mut rejected) = (0usize, 0usize, 0usize);
    for (id, ticket) in results {
        match ticket {
            None => rejected += 1,
            Some(t) => match t.wait() {
                Outcome::Completed { outputs } => {
                    completed += 1;
                    assert_eq!(
                        served_digest(&outputs),
                        reference_digest(seed, id),
                        "iter {iter} ({mode:?}): request {id} served bytes diverged from \
                         the unbatched recompute"
                    );
                }
                Outcome::Shed(ShedReason::DeadlineExpired) => shed += 1,
                Outcome::Shed(ShedReason::Dropped) => {
                    panic!(
                        "iter {iter} ({mode:?}): request {id} Dropped on a graceful \
                         close+shutdown drain"
                    )
                }
            },
        }
    }
    assert_eq!(
        completed + shed + rejected,
        submitted,
        "iter {iter} ({mode:?}): lost tickets ({completed} completed + {shed} shed + \
         {rejected} rejected != {submitted} submitted)"
    );
    assert_eq!(submitted, CLIENTS * PER_CLIENT);
}

fn stress_iters() -> u64 {
    std::env::var("SKYFORMER_STRESS_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(3)
}

#[test]
fn stress_mixed_load_races_shutdown_scoped() {
    for iter in 0..stress_iters() {
        stress_once(iter, pool::Mode::Scoped);
    }
}

#[test]
fn stress_mixed_load_races_shutdown_pinned() {
    for iter in 0..stress_iters() {
        stress_once(iter, pool::Mode::Pinned);
    }
}

/// All-expired fault injection: every request is dead on arrival while
/// shutdown races admission — nothing completes, nothing is lost, and
/// the drain terminates (no gatherer waits on a batch that can never
/// form).
#[test]
fn stress_all_expired_load_drains_clean() {
    let ctx = KernelCtx::with_threads(2).with_mode(pool::Mode::Scoped);
    let cfg = ServeConfig {
        queue_capacity: 8,
        max_batch: 3,
        max_wait: Duration::from_micros(200),
        dispatchers: 2,
        ..ServeConfig::default()
    };
    let server = Server::start(cfg, ctx);
    let results: Vec<Option<Ticket>> = std::thread::scope(|scope| {
        let server = &server;
        let handles: Vec<_> = (0..8)
            .map(|c| {
                scope.spawn(move || {
                    (0..16)
                        .map(|j| {
                            let mut req = gen_request(991, (c * 100 + j) as u64);
                            req.deadline = Some(Instant::now() - Duration::from_millis(1));
                            server.submit(req).ok()
                        })
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        let racer = scope.spawn(move || server.close());
        racer.join().expect("close racer");
        handles.into_iter().flat_map(|h| h.join().expect("client")).collect()
    });
    server.shutdown();
    for ticket in results.into_iter().flatten() {
        assert!(
            matches!(ticket.wait(), Outcome::Shed(ShedReason::DeadlineExpired)),
            "dead-on-arrival request must shed, not complete or drop"
        );
    }
}
