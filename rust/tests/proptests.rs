//! Property-based tests over coordinator/substrate invariants.
//!
//! proptest is unavailable offline, so this file carries a minimal
//! deterministic property harness: each property runs over a sweep of
//! RNG-derived cases and reports the failing case seed.

use skyformer::data::batch::{Dataset, Split};
use skyformer::kernels::{self, ops::reference, KernelCtx};
use skyformer::linalg::{norms, solve, svd, Matrix};
use skyformer::nystrom::{self, Inverse, Kernel};
use skyformer::runtime::manifest::TaskConfig;
use skyformer::util::json;
use skyformer::util::rng::Rng;

/// Run `prop` over `cases` seeds; panic with the seed on first failure.
fn forall(cases: u64, prop: impl Fn(&mut Rng) -> std::result::Result<(), String>) {
    for seed in 0..cases {
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed at case seed {seed}: {msg}");
        }
    }
}

fn check(cond: bool, msg: impl Fn() -> String) -> std::result::Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg())
    }
}

// ---------------------------------------------------------------- linalg

#[test]
fn prop_matmul_associative() {
    forall(20, |rng| {
        let (m, k, n, o) = (
            1 + rng.below(20),
            1 + rng.below(20),
            1 + rng.below(20),
            1 + rng.below(10),
        );
        let a = Matrix::randn(rng, m, k, 1.0);
        let b = Matrix::randn(rng, k, n, 1.0);
        let c = Matrix::randn(rng, n, o, 1.0);
        let left = a.matmul(&b).matmul(&c);
        let right = a.matmul(&b.matmul(&c));
        let scale = left.max_abs().max(1.0);
        check(
            left.sub(&right).max_abs() / scale < 1e-3,
            || format!("associativity broke at {m}x{k}x{n}x{o}"),
        )
    });
}

#[test]
fn prop_spectral_norm_submultiplicative() {
    forall(15, |rng| {
        let (m, k, n) = (2 + rng.below(15), 2 + rng.below(15), 2 + rng.below(15));
        let a = Matrix::randn(rng, m, k, 1.0);
        let b = Matrix::randn(rng, k, n, 1.0);
        let na = norms::spectral_norm(&a);
        let nb = norms::spectral_norm(&b);
        let nab = norms::spectral_norm(&a.matmul(&b));
        check(nab <= na * nb * 1.01, || {
            format!("||AB||={nab} > ||A||*||B||={}", na * nb)
        })
    });
}

#[test]
fn prop_svd_largest_matches_power_iteration() {
    forall(10, |rng| {
        let (m, n) = (3 + rng.below(20), 3 + rng.below(12));
        let a = Matrix::randn(rng, m, n, 1.0);
        let sv = svd::singular_values(&a);
        let sn = norms::spectral_norm(&a);
        check((sv[0] - sn).abs() < 2e-2 * sn.max(1e-6), || {
            format!("{} vs {}", sv[0], sn)
        })
    });
}

#[test]
fn prop_gauss_jordan_left_and_right_inverse() {
    forall(10, |rng| {
        let n = 2 + rng.below(20);
        let x = Matrix::randn(rng, n, n, 1.0);
        let m = x.matmul(&x.transpose()).add_diag(0.5); // well-conditioned PSD
        let inv = solve::gauss_jordan_inverse(&m).ok_or("singular")?;
        let eye = Matrix::eye(n);
        let e1 = m.matmul(&inv).sub(&eye).max_abs();
        let e2 = inv.matmul(&m).sub(&eye).max_abs();
        check(e1 < 1e-2 && e2 < 1e-2, || format!("inverse errors {e1} {e2}"))
    });
}

// ---------------------------------------------------------------- kernels

/// The kernel determinism contract, as a property: every fused parallel
/// kernel is *bit-identical* to the naive scalar oracle at any thread
/// count, over random shapes (including tile-remainder and empty edges).
fn bits_match(got: &Matrix, want: &Matrix, what: &str) -> std::result::Result<(), String> {
    check(
        (got.rows, got.cols) == (want.rows, want.cols),
        || format!("{what}: shape {}x{} vs {}x{}", got.rows, got.cols, want.rows, want.cols),
    )?;
    for (idx, (x, y)) in got.data.iter().zip(&want.data).enumerate() {
        check(x.to_bits() == y.to_bits(), || {
            format!("{what}: bit mismatch at flat index {idx}: {x} vs {y}")
        })?;
    }
    Ok(())
}

#[test]
fn prop_matmul_parallel_bit_exact_vs_scalar_reference() {
    forall(15, |rng| {
        let (m, k, n) = (rng.below(80), rng.below(80), rng.below(40));
        let a = Matrix::randn(rng, m, k, 1.0);
        let b = Matrix::randn(rng, k, n, 1.0);
        let want = reference::matmul(&a, &b);
        for threads in [1usize, 2, 4] {
            let got = kernels::matmul(KernelCtx::with_threads(threads), &a, &b);
            bits_match(&got, &want, &format!("matmul {m}x{k}x{n} @{threads}t"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_gaussian_scores_parallel_bit_exact_vs_scalar_reference() {
    forall(15, |rng| {
        let (m, n, p) = (rng.below(70), rng.below(70), 1 + rng.below(16));
        let a = Matrix::randn(rng, m, p, 0.6);
        let b = Matrix::randn(rng, n, p, 0.6);
        let want = reference::gaussian_scores(&a, &b);
        for threads in [1usize, 4] {
            let got = kernels::gaussian_scores(KernelCtx::with_threads(threads), &a, &b);
            bits_match(&got, &want, &format!("gaussian_scores {m}x{n}x{p} @{threads}t"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_row_softmax_matmul_parallel_bit_exact_vs_scalar_reference() {
    forall(15, |rng| {
        let (m, l, n) = (rng.below(60), 1 + rng.below(60), 1 + rng.below(24));
        let s = Matrix::randn(rng, m, l, 2.0);
        let v = Matrix::randn(rng, l, n, 1.0);
        let want = reference::row_softmax_matmul(&s, &v);
        for threads in [1usize, 4] {
            let got = kernels::row_softmax_matmul(KernelCtx::with_threads(threads), &s, &v);
            bits_match(&got, &want, &format!("row_softmax_matmul {m}x{l}x{n} @{threads}t"))?;
        }
        Ok(())
    });
}

// ------------------------------------------------------------------ pool

use skyformer::kernels::pool;

/// Deterministic per-cell payload so any partition/scheduling slip shows
/// up as a byte difference, not just a missed row.
fn fill_rows(mode: pool::Mode, threads: usize, rows: usize, row_len: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * row_len];
    pool::run_rows_in(mode, threads, rows, row_len, &mut out, |first_row, chunk| {
        for (r, row) in chunk.chunks_mut(row_len).enumerate() {
            let i = first_row + r;
            for (j, x) in row.iter_mut().enumerate() {
                *x = ((i * 37 + j * 11 + 3) as f32).sin() + i as f32;
            }
        }
    });
    out
}

#[test]
fn prop_pinned_pool_bit_identical_to_scoped_over_random_shapes() {
    // random shapes and widths, including threads > rows (oversubscription
    // clamps to the same partition in both modes) and degenerate rows
    forall(25, |rng| {
        let rows = rng.below(60);
        let row_len = 1 + rng.below(24);
        let threads = 1 + rng.below(16);
        let scoped = fill_rows(pool::Mode::Scoped, threads, rows, row_len);
        let pinned = fill_rows(pool::Mode::Pinned, threads, rows, row_len);
        for (idx, (x, y)) in scoped.iter().zip(&pinned).enumerate() {
            check(x.to_bits() == y.to_bits(), || {
                format!("rows={rows} row_len={row_len} threads={threads}: byte {idx}: {x} vs {y}")
            })?;
        }
        Ok(())
    });
}

#[test]
fn prop_pinned_pool_survives_small_back_to_back_job_stress() {
    // the Newton–Schulz shape: long runs of small kernel-sized jobs
    // submitted back to back must neither wedge the parked workers nor
    // drop a chunk; every iteration is checked against the scoped result
    forall(4, |rng| {
        for i in 0..120 {
            let rows = 1 + rng.below(9);
            let row_len = 1 + rng.below(6);
            let threads = 2 + rng.below(6);
            let scoped = fill_rows(pool::Mode::Scoped, threads, rows, row_len);
            let pinned = fill_rows(pool::Mode::Pinned, threads, rows, row_len);
            check(scoped == pinned, || {
                format!("iteration {i}: rows={rows} row_len={row_len} threads={threads}")
            })?;
        }
        Ok(())
    });
}

#[test]
fn prop_kernels_bit_identical_across_pool_modes_at_pool_scale() {
    // above PAR_MIN_FLOPS the ops layer actually dispatches to the pools;
    // outputs must not depend on which backend ran the partition
    forall(3, |rng| {
        let n = 128 + rng.below(16);
        let a = Matrix::randn(rng, n, n, 0.7);
        let b = Matrix::randn(rng, n, n, 0.7);
        for threads in [2usize, 4, 8] {
            let ctx = KernelCtx::with_threads(threads);
            let scoped = kernels::matmul(ctx.with_mode(pool::Mode::Scoped), &a, &b);
            let pinned = kernels::matmul(ctx.with_mode(pool::Mode::Pinned), &a, &b);
            bits_match(&scoped, &pinned, &format!("matmul n={n} @{threads}t"))?;
            let scoped = kernels::matmul_transa(ctx.with_mode(pool::Mode::Scoped), &a, &b);
            let pinned = kernels::matmul_transa(ctx.with_mode(pool::Mode::Pinned), &a, &b);
            bits_match(&scoped, &pinned, &format!("matmul_transa n={n} @{threads}t"))?;
        }
        Ok(())
    });
}

// ---------------------------------------------------------------- nystrom

#[test]
fn prop_lemma3_unit_spectrum_for_kernel_grams() {
    forall(12, |rng| {
        let n = 4 + rng.below(28);
        let p = 2 + rng.below(12);
        let scale = 0.3 + rng.uniform();
        let x = Matrix::randn(rng, n, p, scale);
        let gram = nystrom::kernel_matrix(Kernel::Gaussian, &x, &x);
        let (m_hat, _) = solve::ns_preconditioner(&gram, 1e-3);
        let resid = norms::spectral_norm(&Matrix::eye(n).sub(&m_hat));
        check(resid < 1.0 + 1e-4, || format!("||I - m_hat|| = {resid}"))
    });
}

#[test]
fn prop_nystrom_error_bounded_by_identity_at_full_rank() {
    forall(8, |rng| {
        // exactness at full rank holds in exact arithmetic; in f32 the
        // lifted Gram must stay reasonably conditioned, so keep the point
        // count modest relative to the ambient dimension.
        let n = 4 + rng.below(8);
        let p = 6 + rng.below(6);
        let q = Matrix::randn(rng, n, p, 0.5);
        let k = Matrix::randn(rng, n, p, 0.5);
        let c = nystrom::kernel_matrix(Kernel::Gaussian, &q, &k);
        let landmarks: Vec<usize> = (0..2 * n).collect();
        let approx = nystrom::modified_nystrom_with_landmarks(
            Kernel::Gaussian,
            &q,
            &k,
            &landmarks,
            Inverse::Exact { gamma: 1e-6 },
        );
        let rel = norms::spectral_norm(&c.sub(&approx)) / norms::spectral_norm(&c).max(1e-20);
        check(rel < 5e-2, || format!("full-rank rel err {rel}"))
    });
}

#[test]
fn prop_nystrom_loewner_residual_psd() {
    // Theorem 2 first part: C_bar - C_bar_tilde is PSD (residual of a
    // projection) — check x^T (C - C~) x >= 0 on the lifted matrix.
    forall(8, |rng| {
        let n = 3 + rng.below(10);
        let p = 2 + rng.below(6);
        let q = Matrix::randn(rng, n, p, 0.5);
        let k = Matrix::randn(rng, n, p, 0.5);
        let x = q.vcat(&k);
        let cbar = nystrom::kernel_matrix(Kernel::Gaussian, &x, &x);
        let d = 2 + rng.below(n);
        let lm_idx = rng.choose_distinct(2 * n, d);
        let cs = cbar.take_rows(&lm_idx).transpose(); // (2n, d) columns
        let gram = Matrix::from_fn(d, d, |i, j| cbar[(lm_idx[i], lm_idx[j])]);
        let inv = solve::gauss_jordan_inverse(&gram.add_diag(1e-5)).ok_or("singular")?;
        let tilde = cs.matmul(&inv).matmul(&cs.transpose());
        let resid = cbar.sub(&tilde);
        for _ in 0..10 {
            let z: Vec<f32> = (0..2 * n).map(|_| rng.normal()).collect();
            let rz = resid.matvec(&z);
            let quad: f32 = z.iter().zip(&rz).map(|(a, b)| a * b).sum();
            check(quad > -1e-2 * cbar.max_abs(), || {
                format!("residual not PSD: x^T R x = {quad}")
            })?;
        }
        Ok(())
    });
}

// ------------------------------------------------------------------ data

fn tc(name: &str, seq: usize, vocab: usize, classes: usize, dual: bool, batch: usize) -> TaskConfig {
    TaskConfig {
        name: name.into(),
        seq_len: seq,
        vocab_size: vocab,
        num_classes: classes,
        batch_size: batch,
        dual,
    }
}

#[test]
fn prop_batches_deterministic_across_dataset_instances() {
    forall(6, |rng| {
        let seed = rng.next_u64();
        let t = tc("listops", 64, 20, 10, false, 3);
        let d1 = Dataset::for_task(&t, seed).map_err(|e| e.to_string())?;
        let d2 = Dataset::for_task(&t, seed).map_err(|e| e.to_string())?;
        for i in 0..3 {
            let a = d1.batch(Split::Train, i);
            let b = d2.batch(Split::Train, i);
            check(a.tokens == b.tokens && a.labels == b.labels, || {
                format!("batch {i} differs for seed {seed}")
            })?;
        }
        Ok(())
    });
}

#[test]
fn prop_different_dataset_seeds_give_different_data() {
    forall(6, |rng| {
        let s1 = rng.next_u64();
        let s2 = s1 ^ 0xABCD;
        let t = tc("text", 64, 256, 2, false, 4);
        let d1 = Dataset::for_task(&t, s1).map_err(|e| e.to_string())?;
        let d2 = Dataset::for_task(&t, s2).map_err(|e| e.to_string())?;
        let a = d1.batch(Split::Train, 0);
        let b = d2.batch(Split::Train, 0);
        check(a.tokens != b.tokens, || "seeds collide".into())
    });
}

#[test]
fn prop_listops_tokens_always_parse_to_label() {
    forall(40, |rng| {
        let t = tc("listops", 96, 20, 10, false, 1);
        let seed = rng.next_u64();
        let d = Dataset::for_task(&t, seed).map_err(|e| e.to_string())?;
        let b = d.batch(Split::Train, 0);
        let toks = b.tokens.as_i32().map_err(|e| e.to_string())?;
        let label = b.labels.as_i32().map_err(|e| e.to_string())?[0];
        let parsed = skyformer::data::listops::interpret_tokens(toks)
            .ok_or("tokens do not parse")?;
        check(parsed == label, || format!("label {label} != parsed {parsed}"))
    });
}

// ------------------------------------------------------------------ util

#[test]
fn prop_json_roundtrip_fuzz() {
    forall(30, |rng| {
        // build a random JSON value, serialise, reparse, compare
        fn random_value(rng: &mut Rng, depth: usize) -> json::Value {
            if depth > 2 {
                return json::num((rng.below(100) as f64) / 7.0);
            }
            match rng.below(5) {
                0 => json::Value::Null,
                1 => json::Value::Bool(rng.below(2) == 0),
                2 => json::num(rng.normal() as f64 * 1e3),
                3 => json::s(format!("s{}-\"quoted\"\n", rng.below(1000))),
                _ => json::Value::Array(
                    (0..rng.below(4)).map(|_| random_value(rng, depth + 1)).collect(),
                ),
            }
        }
        let v = json::obj(vec![
            ("a", random_value(rng, 0)),
            ("b", random_value(rng, 0)),
        ]);
        let text = json::to_string(&v);
        let back = json::parse(&text).map_err(|e| e.to_string())?;
        // floats may lose ulps through the f64 formatter; compare re-serialised
        check(json::to_string(&back) == text, || format!("roundtrip broke: {text}"))
    });
}

#[test]
fn prop_prometheus_names_always_escape_cleanly() {
    // any metric name — control chars, unicode, quotes, leading digits —
    // must sanitise onto [a-zA-Z_:][a-zA-Z0-9_:]* and export as parseable
    // exposition lines
    forall(60, |rng| {
        let len = 1 + rng.below(24);
        let name: String = (0..len)
            .map(|_| char::from_u32(rng.below(0x250) as u32).unwrap_or('\u{fffd}'))
            .collect();
        let sane = skyformer::obs::metrics::sanitize_name(&name);
        let mut chars = sane.chars();
        let first = chars.next().ok_or("sanitized name is empty")?;
        check(
            first.is_ascii_alphabetic() || first == '_' || first == ':',
            || format!("bad first char in {sane:?} (from {name:?})"),
        )?;
        for c in chars {
            check(c.is_ascii_alphanumeric() || c == '_' || c == ':', || {
                format!("bad char {c:?} in {sane:?} (from {name:?})")
            })?;
        }
        // the exported line must carry the sanitised name and no raw newline
        let mut reg = skyformer::obs::Registry::default();
        reg.metrics
            .insert(name.clone(), skyformer::obs::Metric::Counter(1));
        let text = reg.to_prometheus();
        check(text.contains(&format!("{sane} 1")), || {
            format!("export missing sanitised line for {name:?}: {text}")
        })?;
        check(
            text.lines().all(|l| l.starts_with("# TYPE") || l.ends_with(" 1")),
            || format!("unexpected exposition line for {name:?}: {text}"),
        )
    });
}

// ----------------------------------------------------------------- serve

use skyformer::serve::batcher::{plan_gather, plan_leader, BucketKey, Slot};
use skyformer::serve::{ModelKind, Priority};
use std::time::{Duration, Instant};

fn random_bucket(rng: &mut Rng) -> BucketKey {
    BucketKey {
        kind: if rng.below(2) == 0 { ModelKind::Exact } else { ModelKind::Kernelized },
        n: [6, 8, 12, 64][rng.below(4)],
        m: [8, 10][rng.below(2)],
        p: [4, 5][rng.below(2)],
        dv: [2, 4][rng.below(2)],
    }
}

/// A random queue snapshot honouring the queue's structural invariant:
/// slice order == arrival order == ascending `enqueued`.  Timestamps
/// are synthetic (all relative to one base), so the starvation policy
/// is exercised as pure data — no sleeps, no real clock.
fn random_slots(rng: &mut Rng, base: Instant, now: Instant) -> Vec<Slot> {
    let len = rng.below(20);
    let mut at = base;
    (0..len)
        .map(|_| {
            at += Duration::from_millis(1 + rng.below(200) as u64);
            let deadline = match rng.below(4) {
                // expired at `now` / still live / never expires
                0 => Some(at + Duration::from_millis(1)),
                1 => Some(now + Duration::from_secs(5)),
                _ => None,
            };
            Slot {
                bucket: random_bucket(rng),
                priority: if rng.below(3) == 0 { Priority::High } else { Priority::Normal },
                enqueued: at,
                deadline,
            }
        })
        .collect()
}

#[test]
fn prop_shard_routing_is_pure_and_partitions_buckets() {
    forall(200, |rng| {
        let key = random_bucket(rng);
        check(key.shard(1) == 0, || "single shard must own everything".into())?;
        for shards in 1..=8usize {
            let s = key.shard(shards);
            check(s < shards, || format!("shard {s} out of range for {shards}"))?;
            // purity: the same bucket — whether the same value or an
            // independently reconstructed equal one — always lands on
            // the same shard, so no bucket can straddle two shards
            let rebuilt = BucketKey { kind: key.kind, n: key.n, m: key.m, p: key.p, dv: key.dv };
            check(rebuilt.shard(shards) == s && key.shard(shards) == s, || {
                format!("routing not a pure function of the bucket at {shards} shards")
            })?;
        }
        Ok(())
    });
}

/// The leader contract over arbitrary interleaved arrivals: expired
/// slots are shed (exactly those), the leader is the oldest live slot
/// of the winning lane, High wins unless the oldest live Normal is both
/// past the starvation bound and older than the oldest live High.
#[test]
fn prop_priority_leader_and_starvation_bound() {
    forall(300, |rng| {
        let base = Instant::now();
        let now = base + Duration::from_secs(60);
        let slots = random_slots(rng, base, now);
        let starve_after = Duration::from_millis(rng.below(3000) as u64);
        let plan = plan_leader(&slots, now, starve_after);

        let expired: Vec<usize> =
            (0..slots.len()).filter(|&i| slots[i].expired(now)).collect();
        check(plan.shed == expired, || {
            format!("shed {:?} != expired {:?}", plan.shed, expired)
        })?;
        let live: Vec<usize> = (0..slots.len()).filter(|&i| !slots[i].expired(now)).collect();
        let oldest = |lane: Priority| live.iter().copied().find(|&i| slots[i].priority == lane);
        let (oldest_high, oldest_normal) = (oldest(Priority::High), oldest(Priority::Normal));

        let Some(leader) = plan.leader else {
            return check(live.is_empty(), || "live slots but no leader".into());
        };
        check(!slots[leader].expired(now), || format!("expired leader {leader}"))?;
        match slots[leader].priority {
            Priority::High => {
                check(Some(leader) == oldest_high, || {
                    format!("leader {leader} is not the oldest live High")
                })?;
                // High may only lead if no starved older Normal exists
                if let Some(n) = oldest_normal {
                    let starving = now.duration_since(slots[n].enqueued) >= starve_after;
                    check(
                        !(starving && slots[n].enqueued < slots[leader].enqueued),
                        || format!("starved older Normal {n} was passed over for {leader}"),
                    )?;
                }
            }
            Priority::Normal => {
                check(Some(leader) == oldest_normal, || {
                    format!("leader {leader} is not the oldest live Normal")
                })?;
                // Normal may only outrank a queued High via the bound
                if let Some(h) = oldest_high {
                    let starving = now.duration_since(slots[leader].enqueued) >= starve_after;
                    check(starving && slots[leader].enqueued < slots[h].enqueued, || {
                        format!("Normal {leader} outranked High {h} without starving")
                    })?;
                }
            }
        }
        Ok(())
    });
}

/// The gather contract over arbitrary interleaved arrivals: at most
/// `room` taken, all taken are live and bucket-matching, the high lane
/// is taken before the normal lane, each lane is FIFO, sheds are
/// exactly the expired slots, and no live matching slot is left behind
/// while room remains.
#[test]
fn prop_priority_gather_preserves_per_lane_fifo() {
    forall(300, |rng| {
        let base = Instant::now();
        let now = base + Duration::from_secs(60);
        let slots = random_slots(rng, base, now);
        let key = if slots.is_empty() || rng.below(4) == 0 {
            random_bucket(rng)
        } else {
            slots[rng.below(slots.len())].bucket
        };
        let room = rng.below(8);
        let plan = plan_gather(&slots, &key, room, now);

        check(plan.take.len() <= room, || {
            format!("took {} with room {room}", plan.take.len())
        })?;
        let expired: Vec<usize> =
            (0..slots.len()).filter(|&i| slots[i].expired(now)).collect();
        check(plan.shed == expired, || {
            format!("shed {:?} != expired {:?}", plan.shed, expired)
        })?;
        for &i in &plan.take {
            check(!slots[i].expired(now), || format!("took expired slot {i}"))?;
            check(slots[i].bucket == key, || format!("took foreign-bucket slot {i}"))?;
        }
        // high lane first, ascending (FIFO) indices within each lane
        let split = plan
            .take
            .iter()
            .position(|&i| slots[i].priority == Priority::Normal)
            .unwrap_or(plan.take.len());
        let (highs, normals) = plan.take.split_at(split);
        check(highs.iter().all(|&i| slots[i].priority == Priority::High), || {
            format!("normal before high in {:?}", plan.take)
        })?;
        check(normals.iter().all(|&i| slots[i].priority == Priority::Normal), || {
            format!("high after the normal tail in {:?}", plan.take)
        })?;
        check(
            highs.windows(2).all(|w| w[0] < w[1]) && normals.windows(2).all(|w| w[0] < w[1]),
            || format!("a lane is not FIFO in {:?}", plan.take),
        )?;
        // completeness: under-full take means nothing matching was left
        if plan.take.len() < room {
            for i in 0..slots.len() {
                let matching = !slots[i].expired(now) && slots[i].bucket == key;
                check(!matching || plan.take.contains(&i), || {
                    format!("live matching slot {i} left behind with room to spare")
                })?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_rng_split_streams_uncorrelated() {
    forall(10, |rng| {
        let base = Rng::new(rng.next_u64());
        let mut a = base.split(1);
        let mut b = base.split(2);
        let n = 2_000;
        let mut matches = 0;
        for _ in 0..n {
            if (a.uniform() < 0.5) == (b.uniform() < 0.5) {
                matches += 1;
            }
        }
        let rate = matches as f64 / n as f64;
        check((0.44..0.56).contains(&rate), || format!("correlation {rate}"))
    });
}
