//! End-to-end integration over the real artifacts: python-AOT HLO ->
//! PJRT load -> init/train/eval/embed round trips.
//!
//! These tests require `make artifacts` (at least the smoke set:
//! `listops_skyformer` fused + pallas).  They skip gracefully when the
//! artifacts are absent so `cargo test` stays green on a fresh clone.
//! The whole crate is compiled out without the `pjrt` feature.
#![cfg(feature = "pjrt")]

use skyformer::coordinator::instability::InstabilityProbe;
use skyformer::coordinator::trainer::{TrainConfig, Trainer};
use skyformer::data::batch::Split;
use skyformer::runtime::engine::Engine;
use skyformer::runtime::tensor::Tensor;

fn engine() -> Option<Engine> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Engine::new(&dir) {
        Ok(e) => Some(e),
        Err(_) => {
            eprintln!("skipping integration test: artifacts not built");
            None
        }
    }
}

fn have(engine: &Engine, task: &str, attn: &str, pallas: bool) -> bool {
    engine.manifest().find(task, attn, "train", pallas).is_ok()
}

#[test]
fn init_is_deterministic_per_seed() {
    let Some(engine) = engine() else { return };
    if !have(&engine, "listops", "skyformer", false) {
        return;
    }
    let exec = engine.load("listops", "skyformer", "init", false).unwrap();
    let a = exec.run(&[Tensor::scalar_u32(5)]).unwrap();
    let b = exec.run(&[Tensor::scalar_u32(5)]).unwrap();
    let c = exec.run(&[Tensor::scalar_u32(6)]).unwrap();
    assert_eq!(a.len(), exec.spec.outputs.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x, y);
    }
    let differs = a.iter().zip(&c).any(|(x, y)| x != y);
    assert!(differs, "different seeds must differ");
}

#[test]
fn train_step_roundtrip_updates_state_and_loss_is_finite() {
    let Some(engine) = engine() else { return };
    if !have(&engine, "listops", "skyformer", false) {
        return;
    }
    let cfg = TrainConfig::new("listops", "skyformer");
    let mut trainer = Trainer::new(&engine, cfg).unwrap();
    let before = trainer.state()[0].clone();
    let (loss, acc) = trainer.step(0).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert!((0.0..=1.0).contains(&acc));
    let after = &trainer.state()[0];
    assert_ne!(&before, after, "params must change after a step");
}

#[test]
fn short_training_reduces_loss() {
    let Some(engine) = engine() else { return };
    if !have(&engine, "listops", "skyformer", false) {
        return;
    }
    let mut cfg = TrainConfig::new("listops", "skyformer");
    cfg.steps = 12;
    cfg.eval_every = 6;
    cfg.eval_batches = 2;
    let mut trainer = Trainer::new(&engine, cfg).unwrap();
    let r = trainer.train().unwrap();
    let first = r.metrics.steps.first().unwrap().loss;
    let last = r.metrics.steps.last().unwrap().loss;
    assert!(
        last < first,
        "loss should drop within 12 steps: {first} -> {last}"
    );
    assert!(r.metrics.evals.len() >= 2);
    assert!(r.metrics.peak_bytes > 0);
}

#[test]
fn eval_is_deterministic() {
    let Some(engine) = engine() else { return };
    if !have(&engine, "listops", "skyformer", false) {
        return;
    }
    let cfg = TrainConfig::new("listops", "skyformer");
    let trainer = Trainer::new(&engine, cfg).unwrap();
    let (l1, a1) = trainer.evaluate(Split::Valid, 2).unwrap();
    let (l2, a2) = trainer.evaluate(Split::Valid, 2).unwrap();
    assert_eq!(l1, l2);
    assert_eq!(a1, a2);
}

#[test]
fn pallas_and_fused_artifacts_agree_on_eval() {
    let Some(engine) = engine() else { return };
    if !have(&engine, "listops", "skyformer", false)
        || !have(&engine, "listops", "skyformer", true)
    {
        return;
    }
    // same seed -> same init; eval both paths on the same batch.
    // the skyformer eval is stochastic in its landmarks but both lowerings
    // consume the same in-graph PRNG stream, so outputs must match closely.
    let fused_init = engine.load("listops", "skyformer", "init", false).unwrap();
    let state = fused_init.run(&[Tensor::scalar_u32(3)]).unwrap();
    let n_p = fused_init.spec.num_params;

    let run_eval = |pallas: bool| -> (f32, f32) {
        let exec = engine.load("listops", "skyformer", "eval", pallas).unwrap();
        let task = exec.spec.task_config.clone();
        let ds = skyformer::data::batch::Dataset::for_task(&task, 0).unwrap();
        let b = ds.batch(Split::Valid, 0);
        let mut inputs: Vec<Tensor> = state[..n_p].to_vec();
        inputs.push(b.tokens);
        inputs.push(b.labels);
        inputs.push(Tensor::scalar_u32(11));
        let out = exec.run(&inputs).unwrap();
        (
            out[0].scalar_value_f32().unwrap(),
            out[1].scalar_value_f32().unwrap(),
        )
    };
    let (lf, af) = run_eval(false);
    let (lp, ap) = run_eval(true);
    assert!(
        (lf - lp).abs() < 1e-3 * lf.abs().max(1.0),
        "pallas vs fused eval loss: {lf} vs {lp}"
    );
    assert_eq!(af, ap, "accuracy must match exactly");
}

#[test]
fn embed_artifact_shapes() {
    let Some(engine) = engine() else { return };
    if !have(&engine, "listops", "skyformer", false) {
        return;
    }
    let exec = engine.load("listops", "skyformer", "embed", false).unwrap();
    let init = engine.load("listops", "skyformer", "init", false).unwrap();
    let state = init.run(&[Tensor::scalar_u32(0)]).unwrap();
    let n_p = exec.spec.num_params;
    let task = exec.spec.task_config.clone();
    let ds = skyformer::data::batch::Dataset::for_task(&task, 0).unwrap();
    let b = ds.batch(Split::Train, 0);
    let mut inputs: Vec<Tensor> = state[..n_p].to_vec();
    inputs.push(b.tokens);
    inputs.push(Tensor::scalar_u32(0));
    let out = exec.run(&inputs).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].shape()[0], task.batch_size);
}

#[test]
fn instability_probe_runs_and_produces_positive_taus() {
    let Some(engine) = engine() else { return };
    if !have(&engine, "listops", "skyformer", false) {
        return;
    }
    let cfg = TrainConfig::new("listops", "skyformer");
    let mut probe = InstabilityProbe::new(&engine, cfg).unwrap();
    let r = probe.run(3, 1e-4).unwrap();
    assert_eq!(r.taus.len(), 3);
    assert!(r.taus.iter().all(|t| t.is_finite() && *t > 0.0), "{:?}", r.taus);
}

#[test]
fn checkpoint_roundtrip_through_trainer() {
    let Some(engine) = engine() else { return };
    if !have(&engine, "listops", "skyformer", false) {
        return;
    }
    let dir = std::env::temp_dir().join("skyformer_integration_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("t.ckpt");

    let mut cfg = TrainConfig::new("listops", "skyformer");
    cfg.steps = 3;
    cfg.eval_every = 3;
    cfg.eval_batches = 1;
    cfg.checkpoint_path = Some(path.clone());
    let mut trainer = Trainer::new(&engine, cfg).unwrap();
    trainer.train().unwrap();

    let mut cfg2 = TrainConfig::new("listops", "skyformer");
    cfg2.seed = 99;
    let mut trainer2 = Trainer::new(&engine, cfg2).unwrap();
    trainer2.restore(&path).unwrap();
    // restored eval must be deterministic and runnable
    let (l, a) = trainer2.evaluate(Split::Valid, 1).unwrap();
    assert!(l.is_finite());
    assert!((0.0..=1.0).contains(&a));
}

#[test]
fn rejects_wrong_input_shapes() {
    let Some(engine) = engine() else { return };
    if !have(&engine, "listops", "skyformer", false) {
        return;
    }
    let exec = engine.load("listops", "skyformer", "init", false).unwrap();
    // wrong dtype
    let err = exec.run(&[Tensor::scalar_f32(0.0)]);
    assert!(err.is_err());
    // wrong arity
    let err = exec.run(&[]);
    assert!(err.is_err());
}
