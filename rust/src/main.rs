//! `skyformer` — the Layer-3 coordinator CLI.
//!
//! Subcommands map one-to-one onto the paper's experiments (DESIGN.md §4):
//!
//! ```text
//! skyformer info                              # list built artifacts
//! skyformer train   --task listops --attention skyformer --steps 300
//! skyformer sweep   --tasks listops --attentions softmax,skyformer --seeds 3
//! skyformer approx  --n 256 --features 16,32,64,128,256    # Figure 1
//! skyformer instability --task listops                     # Table 3
//! skyformer svd     --task listops --attention softmax     # Figure 4
//! ```

#[cfg(feature = "pjrt")]
use std::path::PathBuf;

use skyformer::attention::{self, exact, probes};
#[cfg(feature = "pjrt")]
use skyformer::coordinator::instability::InstabilityProbe;
#[cfg(feature = "pjrt")]
use skyformer::coordinator::scheduler::Schedule;
#[cfg(feature = "pjrt")]
use skyformer::coordinator::trainer::{TrainConfig, Trainer};
#[cfg(feature = "pjrt")]
use skyformer::data::batch::Split;
#[cfg(feature = "pjrt")]
use skyformer::linalg::svd;
use skyformer::kernels::{self, KernelCtx};
use skyformer::linalg::norms;
use skyformer::linalg::Matrix;
#[cfg(feature = "pjrt")]
use skyformer::report::tables::{fmt_bytes, fmt_secs};
use skyformer::report::tables::Table;
#[cfg(feature = "pjrt")]
use skyformer::runtime::engine::Engine;
use skyformer::util::args::Args;
use skyformer::util::rng::Rng;
use skyformer::Result;

fn main() {
    let args = Args::from_env();
    match args.get_usize("threads", 0) {
        Ok(0) => {}
        Ok(n) => kernels::set_threads(n),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
    if let Some(mode) = args.get("pool") {
        match skyformer::kernels::pool::Mode::parse(mode) {
            Some(m) => kernels::pool::set_mode(m),
            None => {
                eprintln!("error: bad --pool `{mode}` (scoped|pinned)");
                std::process::exit(1);
            }
        }
    }
    let env_prefix = skyformer::obs::init_from_env();
    let obs_out = args.get("obs-out").map(|s| s.to_string()).or(env_prefix);
    if obs_out.is_some() {
        skyformer::obs::set_enabled(true);
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match run(cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    if let Some(prefix) = obs_out {
        match skyformer::obs::dump(&prefix) {
            Ok(paths) => eprintln!("obs: wrote {}", paths.join(", ")),
            Err(e) => eprintln!("obs: dump failed: {e}"),
        }
    }
    std::process::exit(code);
}

#[cfg(feature = "pjrt")]
fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("artifacts", "artifacts"))
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        #[cfg(feature = "pjrt")]
        "info" => info(args),
        #[cfg(feature = "pjrt")]
        "train" => train(args),
        #[cfg(feature = "pjrt")]
        "sweep" => sweep(args),
        "approx" => approx(args),
        "kernels" => kernels_cmd(args),
        "serve-bench" => serve_bench(args),
        #[cfg(feature = "pjrt")]
        "instability" => instability(args),
        #[cfg(feature = "pjrt")]
        "svd" => svd_cmd(args),
        #[cfg(not(feature = "pjrt"))]
        "info" | "train" | "sweep" | "instability" | "svd" => Err(skyformer::Error::Config(
            format!("`{cmd}` needs PJRT: rebuild with `--features pjrt`"),
        )),
        _ => {
            print!("{HELP}");
            Ok(())
        }
    }
}

const HELP: &str = r#"skyformer — Skyformer (NeurIPS 2021) reproduction coordinator

USAGE: skyformer <command> [--flags]

COMMANDS
  info          list built artifacts and their configs
  train         train one (task, attention) model
                  --task listops --attention skyformer [--steps 200]
                  [--seed 0] [--lr 1e-4] [--eval-every 50] [--pallas]
                  [--checkpoint out.ckpt] [--verbose]
  sweep         Table 1/2: train a grid and print accuracy/time/memory rows
                  --tasks listops,text --attentions softmax,skyformer
                  [--seeds 1] [--steps 200] [--curves out.json]
  approx        Figure 1: spectral-norm error vs #features
                  [--n 256] [--features 16,32,64,128,256]
                  [--regimes init,pretrained] [--trials 3]
  kernels       exercise the native kernel subsystem on seeded inputs
                  [--n 96] [--p 16] [--seed 42]
                  [--suite libm|portable]  libm (default) = the full suite
                              (exp paths; fixture pinned per-platform);
                              portable = pure-IEEE-arithmetic kernels whose
                              fixture is identical on every platform
                  [--digest]  print only `name digest` lines (stdout) for
                              the CI cross-thread determinism diff
  serve-bench   drive the serving subsystem with synthetic client load and
                write BENCH_serve.json (p50/p99 latency, throughput)
                  [--requests 1000] [--clients 8] [--seq 128[,256,...]]
                  [--dim 32] [--dv DIM] [--heads 2]
                  [--model exact|kernelized|mixed]
                  [--max-batch 8] [--max-wait-us 200] [--queue-cap 512]
                  [--dispatchers N]   dispatcher shards, each owning a
                                      disjoint bucket set (default
                                      min(2, cores); digests are identical
                                      for every N)
                  [--priority-mix P]  percent of requests submitted on the
                                      High lane (0-100, default 0;
                                      scheduling only — never bytes)
                  [--deadline-ms 0]   0 = none; >0 sheds requests whose
                                      deadline passes before compute
                  [--seed 42] [--out BENCH_serve.json]
                  [--verify]  recompute every completed request unbatched
                              and require bit-identical outputs
                  [--smoke]   CI mode: no deadlines, retry on backpressure,
                              implies --verify, asserts zero lost requests,
                              prints `serve_digest <hex>` for schedule diffs
  instability   Table 3: 20-step instability-score ratios vs self-attention
                  --task listops [--attentions kernelized,skyformer,nystromformer]
  svd           Figure 4: singular-value decay of attention output
                  --task listops --attention softmax [--steps 100]
GLOBAL
  --artifacts DIR   artifact directory (default: artifacts)
  --threads N       kernel pool width (wins over SKYFORMER_THREADS; the
                    determinism contract makes outputs bit-identical for
                    every N)
  --pool MODE       kernel pool backend, scoped|pinned (wins over
                    SKYFORMER_POOL; default pinned — persistent parked
                    workers; outputs are bit-identical in both modes)
  --obs-out PREFIX  dump observability sinks on exit: PREFIX.trace.json
                    (chrome://tracing), PREFIX.events.jsonl,
                    PREFIX.metrics.json, PREFIX.metrics.prom; implies tracing
ENV
  SKYFORMER_TRACE=1        enable span tracing
  SKYFORMER_OBS_OUT=PREFIX same as --obs-out (flag wins)
  SKYFORMER_THREADS=N      kernel pool width (default: available cores)
  SKYFORMER_POOL=MODE      kernel pool backend, scoped|pinned (default pinned)
"#;

/// `skyformer kernels`: run every kernel on seeded inputs and report
/// bit-pattern digests plus parity against the scalar oracles.  With
/// `--digest`, only `name digest` lines go to stdout (config goes to
/// stderr) so CI can diff runs at different `--threads` byte-for-byte.
fn kernels_cmd(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 96)?;
    let p = args.get_usize("p", 16)?;
    let seed = args.get_u64("seed", 42)?;
    let suite = args.get_or("suite", "libm");
    let ctx = KernelCtx::global();
    eprintln!(
        "kernels: suite={suite} n={n} p={p} threads={} pool={}",
        ctx.threads,
        ctx.mode.name()
    );

    // the suites live in the library so the golden-fixture integration
    // test (rust/tests/golden.rs) exercises the exact same workloads
    let outs = match suite {
        "libm" => kernels::digest_suite(ctx, n, p, seed),
        "portable" => kernels::digest_suite_portable(ctx, n, seed),
        other => {
            return Err(skyformer::Error::Config(format!(
                "bad --suite `{other}` (libm|portable)"
            )))
        }
    };

    if args.get_bool("digest") {
        for (name, out, _) in &outs {
            println!("{name} {:016x}", kernels::digest(out));
        }
        return Ok(());
    }

    let mut t = Table::new(
        &format!(
            "Kernel subsystem: n={n} p={p} threads={} pool={}",
            ctx.threads,
            ctx.mode.name()
        ),
        &["kernel", "shape", "digest", "scalar parity"],
    );
    let mut all_exact = true;
    for (name, out, want) in &outs {
        let exact = kernels::digest(out) == kernels::digest(want);
        all_exact &= exact;
        t.row(vec![
            name.to_string(),
            format!("{}x{}", out.rows, out.cols),
            format!("{:016x}", kernels::digest(out)),
            if exact { "bit-exact".into() } else { "DIVERGED".into() },
        ]);
    }
    println!("{}", t.render());
    if !all_exact {
        return Err(skyformer::Error::Config(
            "kernel output diverged from the scalar oracle".into(),
        ));
    }
    Ok(())
}

/// `skyformer serve-bench`: drive the serving subsystem
/// (`skyformer::serve`) with N synthetic open-loop clients and write a
/// `BENCH_serve.json` artifact.  Every request resolves as completed,
/// shed, or rejected — a request falling through is a hard error.  With
/// `--verify` (implied by `--smoke`), every completed request is
/// recomputed through the *unbatched* per-request attention path and
/// required to match bit-for-bit, and a combined `serve_digest` line is
/// printed so CI can diff schedules (threads × pool backends).
fn serve_bench(args: &Args) -> Result<()> {
    use skyformer::serve::{
        Head, ModelKind, Outcome, Priority, RejectReason, Request, ServeConfig, Server, Ticket,
    };
    use std::time::{Duration, Instant};

    let requests = args.get_usize("requests", 1000)?;
    let clients = args.get_usize("clients", 8)?.max(1);
    let seqs: Vec<usize> = match args.get_list("seq") {
        None => vec![128],
        Some(list) => list
            .iter()
            .map(|v| {
                v.parse::<usize>()
                    .ok()
                    .filter(|&n| n > 0)
                    .ok_or_else(|| skyformer::Error::Config(format!("bad --seq `{v}`")))
            })
            .collect::<Result<_>>()?,
    };
    if seqs.is_empty() {
        return Err(skyformer::Error::Config("--seq list is empty".into()));
    }
    let dim = args.get_usize("dim", 32)?;
    let dv = args.get_usize("dv", dim)?;
    let heads = args.get_usize("heads", 2)?.max(1);
    let model = args.get_or("model", "exact").to_string();
    if !matches!(model.as_str(), "exact" | "kernelized" | "mixed") {
        return Err(skyformer::Error::Config(format!(
            "bad --model `{model}` (exact|kernelized|mixed)"
        )));
    }
    let max_batch = args.get_usize("max-batch", 8)?;
    let max_wait_us = args.get_u64("max-wait-us", 200)?;
    let queue_cap = args.get_usize("queue-cap", 512)?;
    let dispatchers = args.get_usize("dispatchers", ServeConfig::default_dispatchers())?;
    if dispatchers == 0 {
        return Err(skyformer::Error::Config("--dispatchers must be > 0".into()));
    }
    let priority_mix = args.get_u64("priority-mix", 0)?;
    if priority_mix > 100 {
        return Err(skyformer::Error::Config(format!(
            "bad --priority-mix `{priority_mix}` (percent, 0-100)"
        )));
    }
    let deadline_ms = args.get_u64("deadline-ms", 0)?;
    let seed = args.get_u64("seed", 42)?;
    let smoke = args.get_bool("smoke");
    let verify = smoke || args.get_bool("verify");
    let out_path = args.get_or("out", "BENCH_serve.json").to_string();

    let ctx = KernelCtx::global();
    let kind_of = |id: u64| match model.as_str() {
        "kernelized" => ModelKind::Kernelized,
        "mixed" if id % 2 == 1 => ModelKind::Kernelized,
        _ => ModelKind::Exact,
    };
    // lane assignment is a pure function of the id (like the request
    // data), so the workload — and therefore the digest — is identical
    // however clients interleave
    let prio_of = |id: u64| {
        if id % 100 < priority_mix {
            Priority::High
        } else {
            Priority::Normal
        }
    };
    // request data depends on (seed, id) alone — not on which client
    // thread generates it or when — so the workload is reproducible and
    // the unbatched verify pass can regenerate any request
    let gen_heads = |id: u64| -> Vec<Head> {
        let root = Rng::new(seed).split(id);
        let n = seqs[id as usize % seqs.len()];
        (0..heads)
            .map(|h| {
                let mut r = root.split(h as u64 + 1);
                Head {
                    q: Matrix::randn(&mut r, n, dim, 0.5),
                    k: Matrix::randn(&mut r, n, dim, 0.5),
                    v: Matrix::randn(&mut r, n, dv, 1.0),
                }
            })
            .collect()
    };

    const FNV: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let fold = |h: u64, x: u64| (h ^ x).wrapping_mul(FNV_PRIME);

    eprintln!(
        "serve-bench: {requests} requests, {clients} clients, model={model}, \
         seq={seqs:?}, heads={heads}, max_batch={max_batch}, max_wait={max_wait_us}us, \
         queue_cap={queue_cap}, dispatchers={dispatchers}, priority_mix={priority_mix}%, \
         deadline_ms={deadline_ms}, threads={}, pool={}{}",
        ctx.threads,
        ctx.mode.name(),
        if smoke { " [smoke]" } else { "" }
    );

    let cfg = ServeConfig {
        queue_capacity: queue_cap,
        max_batch,
        max_wait: Duration::from_micros(max_wait_us),
        dispatchers,
        ..ServeConfig::default()
    };
    let server = Server::start(cfg, ctx);

    #[derive(Clone, Copy, PartialEq, Eq)]
    enum Final {
        Completed,
        Shed,
        Rejected,
    }
    // (id, final state, client-observed latency, served output digest)
    let t0 = Instant::now();
    let results: Vec<(u64, Final, f64, u64)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let server = &server;
                let gen_heads = &gen_heads;
                let kind_of = &kind_of;
                let prio_of = &prio_of;
                scope.spawn(move || {
                    // open loop: submit this client's id stride first,
                    // then collect — queued depth is what exercises the
                    // batcher and (at low queue_cap) backpressure
                    let mut tickets: Vec<(u64, Instant, Option<Ticket>)> = Vec::new();
                    let mut id = c as u64;
                    while (id as usize) < requests {
                        let deadline = (!smoke && deadline_ms > 0)
                            .then(|| Instant::now() + Duration::from_millis(deadline_ms));
                        let mut req = Request {
                            id,
                            kind: kind_of(id),
                            heads: gen_heads(id),
                            deadline,
                            priority: prio_of(id),
                        };
                        let submitted = Instant::now();
                        let ticket = loop {
                            match server.submit(req) {
                                Ok(t) => break Some(t),
                                Err(RejectReason::QueueFull) if smoke => {
                                    // smoke asserts zero lost requests, so
                                    // backpressure means retry, not give up
                                    std::thread::sleep(Duration::from_micros(50));
                                    req = Request {
                                        id,
                                        kind: kind_of(id),
                                        heads: gen_heads(id),
                                        deadline: None,
                                        priority: prio_of(id),
                                    };
                                }
                                Err(_) => break None,
                            }
                        };
                        tickets.push((id, submitted, ticket));
                        id += clients as u64;
                    }
                    let mut local = Vec::new();
                    for (id, submitted, ticket) in tickets {
                        let entry = match ticket {
                            None => (id, Final::Rejected, 0.0, 0),
                            Some(t) => match t.wait() {
                                Outcome::Completed { outputs } => {
                                    let lat = submitted.elapsed().as_secs_f64();
                                    let digest = outputs
                                        .iter()
                                        .fold(FNV, |h, o| fold(h, kernels::digest(o)));
                                    (id, Final::Completed, lat, digest)
                                }
                                Outcome::Shed(_) => (id, Final::Shed, 0.0, 0),
                            },
                        };
                        local.push(entry);
                    }
                    local
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().expect("client thread")).collect()
    });
    let wall = t0.elapsed().as_secs_f64();
    server.shutdown();

    let count = |f: Final| results.iter().filter(|r| r.1 == f).count();
    let (completed, shed, rejected) = (count(Final::Completed), count(Final::Shed), count(Final::Rejected));
    if completed + shed + rejected != requests {
        return Err(skyformer::Error::Config(format!(
            "lost requests: {completed} completed + {shed} shed + {rejected} rejected != {requests}"
        )));
    }
    if smoke && (shed > 0 || rejected > 0) {
        return Err(skyformer::Error::Config(format!(
            "smoke expects every request to complete: {shed} shed, {rejected} rejected"
        )));
    }

    let mut lats: Vec<f64> =
        results.iter().filter(|r| r.1 == Final::Completed).map(|r| r.2).collect();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |q: f64| {
        if lats.is_empty() {
            0.0
        } else {
            lats[((lats.len() - 1) as f64 * q).round() as usize]
        }
    };
    let (p50, p99) = (pct(0.50), pct(0.99));
    let mean = if lats.is_empty() { 0.0 } else { lats.iter().sum::<f64>() / lats.len() as f64 };
    let lat_max = lats.last().copied().unwrap_or(0.0);

    // verify: recompute every completed request through the unbatched
    // per-request path and fold a combined digest in id order (batch
    // composition is timing-dependent; per-request bits are not)
    let mut combined = FNV;
    if verify {
        let mut done: Vec<(u64, u64)> = results
            .iter()
            .filter(|r| r.1 == Final::Completed)
            .map(|r| (r.0, r.3))
            .collect();
        done.sort_unstable_by_key(|r| r.0);
        let mut mismatched = 0usize;
        for &(id, served) in &done {
            let want = gen_heads(id).iter().fold(FNV, |h, hd| {
                let out = match kind_of(id) {
                    ModelKind::Exact => exact::softmax_attention_in(ctx, &hd.q, &hd.k, &hd.v),
                    ModelKind::Kernelized => {
                        exact::kernelized_attention_in(ctx, &hd.q, &hd.k, &hd.v)
                    }
                };
                fold(h, kernels::digest(&out))
            });
            if want != served {
                mismatched += 1;
            }
            combined = fold(combined, served);
        }
        println!("serve_digest {combined:016x}");
        if mismatched > 0 {
            return Err(skyformer::Error::Config(format!(
                "batched dispatch diverged from per-request dispatch on {mismatched} of {} \
                 completed requests",
                done.len()
            )));
        }
    }

    use skyformer::util::json::{num, obj, s, to_string, Value};
    let doc = obj(vec![
        ("bench", s("serve")),
        ("requests", num(requests as f64)),
        ("clients", num(clients as f64)),
        ("model", s(model.clone())),
        ("seq", Value::Array(seqs.iter().map(|&n| num(n as f64)).collect())),
        ("dim", num(dim as f64)),
        ("dv", num(dv as f64)),
        ("heads", num(heads as f64)),
        ("max_batch", num(max_batch as f64)),
        ("max_wait_us", num(max_wait_us as f64)),
        ("queue_capacity", num(queue_cap as f64)),
        ("dispatchers", num(dispatchers as f64)),
        ("priority_mix_pct", num(priority_mix as f64)),
        ("deadline_ms", num(deadline_ms as f64)),
        ("threads", num(ctx.threads as f64)),
        ("pool", s(ctx.mode.name())),
        ("completed", num(completed as f64)),
        ("shed", num(shed as f64)),
        ("rejected", num(rejected as f64)),
        ("wall_seconds", num(wall)),
        ("throughput_rps", num(completed as f64 / wall.max(1e-9))),
        (
            "latency_seconds",
            obj(vec![
                ("p50", num(p50)),
                ("p99", num(p99)),
                ("mean", num(mean)),
                ("max", num(lat_max)),
            ]),
        ),
        (
            "digest",
            if verify { s(format!("{combined:016x}")) } else { Value::Null },
        ),
        ("metrics", skyformer::obs::snapshot().to_json()),
    ]);
    std::fs::write(&out_path, to_string(&doc))?;

    println!(
        "serve-bench: {completed} completed, {shed} shed, {rejected} rejected in {wall:.3}s \
         ({:.0} req/s); latency p50={p50:.6}s p99={p99:.6}s; wrote {out_path}",
        completed as f64 / wall.max(1e-9)
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
fn info(args: &Args) -> Result<()> {
    let engine = Engine::new(artifacts_dir(args))?;
    println!("platform: {}", engine.platform());
    let mut t = Table::new(
        "Artifacts",
        &["name", "kind", "task", "attention", "inputs", "outputs", "bytes"],
    );
    for (name, spec) in &engine.manifest().artifacts {
        t.row(vec![
            name.clone(),
            spec.kind.clone(),
            spec.task.clone(),
            spec.attention.clone(),
            spec.inputs.len().to_string(),
            spec.outputs.len().to_string(),
            fmt_bytes(spec.input_bytes()),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

#[cfg(feature = "pjrt")]
fn train_config_from(args: &Args) -> Result<TrainConfig> {
    let task = args.get_or("task", "listops").to_string();
    let attention = args.get_or("attention", "skyformer").to_string();
    let mut cfg = TrainConfig::new(&task, &attention);
    cfg.pallas = args.get_bool("pallas");
    cfg.steps = args.get_usize("steps", cfg.steps)?;
    cfg.eval_every = args.get_usize("eval-every", cfg.eval_every)?;
    cfg.eval_batches = args.get_usize("eval-batches", cfg.eval_batches)?;
    cfg.seed = args.get_u64("seed", 0)?;
    cfg.verbose = args.get_bool("verbose");
    if let Some(lr) = args.get("lr") {
        let lr: f32 = lr
            .parse()
            .map_err(|_| skyformer::Error::Config("bad --lr".into()))?;
        cfg.schedule = Schedule::Warmup { base: lr, warmup_steps: 20 };
    }
    cfg.checkpoint_path = args.get("checkpoint").map(PathBuf::from);
    Ok(cfg)
}

#[cfg(feature = "pjrt")]
fn train(args: &Args) -> Result<()> {
    let engine = Engine::new(artifacts_dir(args))?;
    let mut cfg = train_config_from(args)?;
    cfg.verbose = true;
    let mut trainer = Trainer::new(&engine, cfg)?;
    let result = trainer.train()?;
    println!(
        "done: best_eval_acc={:.4} test_acc={:.4} final_loss={:.4} time={} peak={}",
        result.best_eval_acc,
        result.test_acc,
        result.final_eval_loss,
        fmt_secs(result.total_seconds),
        fmt_bytes(result.metrics.peak_bytes),
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
fn sweep(args: &Args) -> Result<()> {
    let engine = Engine::new(artifacts_dir(args))?;
    let tasks = args
        .get_list("tasks")
        .unwrap_or_else(|| vec!["listops".into()]);
    let attentions = args.get_list("attentions").unwrap_or_else(|| {
        vec!["softmax".into(), "kernelized".into(), "skyformer".into()]
    });
    let seeds = args.get_u64("seeds", 1)?;
    let steps = args.get_usize("steps", 200)?;

    let mut acc_table = Table::new(
        "Table 1 (lite): classification accuracy (%)",
        &["model", "task", "test_acc", "best_eval_acc", "seeds"],
    );
    let mut cost_table = Table::new(
        "Table 2 (lite): per-step time and peak tensor memory",
        &["model", "task", "s/step", "total", "peak_mem"],
    );
    let mut curves: Vec<skyformer::util::json::Value> = Vec::new();

    for task in &tasks {
        for attn in &attentions {
            let mut accs = Vec::new();
            let mut best_accs = Vec::new();
            let mut step_secs = Vec::new();
            let mut totals = Vec::new();
            let mut peak = 0usize;
            for seed in 0..seeds {
                let mut cfg = train_config_from(args)?;
                cfg.task = task.clone();
                cfg.attention = attn.clone();
                cfg.steps = steps;
                cfg.seed = seed;
                let mut trainer = match Trainer::new(&engine, cfg) {
                    Ok(t) => t,
                    Err(e) => {
                        eprintln!("skip {task}/{attn}: {e}");
                        continue;
                    }
                };
                let r = trainer.train()?;
                eprintln!(
                    "{task}/{attn} seed {seed}: test {:.3} best {:.3} ({})",
                    r.test_acc,
                    r.best_eval_acc,
                    fmt_secs(r.total_seconds)
                );
                accs.push(r.test_acc);
                best_accs.push(r.best_eval_acc);
                step_secs.push(r.metrics.mean_step_seconds());
                totals.push(r.total_seconds);
                peak = peak.max(r.metrics.peak_bytes);
                curves.push(skyformer::util::json::obj(vec![
                    ("task", skyformer::util::json::s(task.clone())),
                    ("attention", skyformer::util::json::s(attn.clone())),
                    ("seed", skyformer::util::json::num(seed as f64)),
                    ("metrics", r.metrics.to_json()),
                ]));
            }
            if accs.is_empty() {
                continue;
            }
            let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len() as f32;
            let meand = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
            acc_table.row(vec![
                attn.clone(),
                task.clone(),
                format!("{:.2}", 100.0 * mean(&accs)),
                format!("{:.2}", 100.0 * mean(&best_accs)),
                accs.len().to_string(),
            ]);
            cost_table.row(vec![
                attn.clone(),
                task.clone(),
                format!("{:.3}", meand(&step_secs)),
                fmt_secs(meand(&totals)),
                fmt_bytes(peak),
            ]);
        }
    }
    println!("{}", acc_table.render());
    println!("{}", cost_table.render());
    if let Some(path) = args.get("curves") {
        let doc = skyformer::util::json::Value::Array(curves);
        std::fs::write(path, skyformer::util::json::to_string(&doc))?;
        println!("curves written to {path}");
    }
    Ok(())
}

fn approx(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 256)?;
    let p = args.get_usize("p", 32)?;
    let trials = args.get_u64("trials", 3)?;
    let features: Vec<usize> = args
        .get_list("features")
        .unwrap_or_else(|| vec!["16".into(), "32".into(), "64".into(), "128".into(), "256".into()])
        .iter()
        .map(|s| s.parse().unwrap_or(64))
        .collect();
    let regimes: Vec<probes::Regime> = args
        .get_list("regimes")
        .unwrap_or_else(|| vec!["init".into(), "pretrained".into()])
        .iter()
        .filter_map(|r| match r.as_str() {
            "init" => Some(probes::Regime::Init),
            "pretrained" => Some(probes::Regime::Pretrained),
            _ => None,
        })
        .collect();

    for regime in regimes {
        let mut headers = vec!["method".to_string()];
        headers.extend(features.iter().map(|f| format!("d={f}")));
        let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(
            &format!(
                "Figure 1 (lite): relative spectral error, n={n}, {} weights",
                regime.name()
            ),
            &header_refs,
        );
        let mut rng = Rng::new(args.get_u64("seed", 0)?).split_str(regime.name());
        let pr = probes::probe(regime, n, p, &mut rng);
        let target = exact::softmax_attention(&pr.q, &pr.k, &pr.v);
        for method in attention::METHODS {
            let mut cells = vec![method.name().to_string()];
            for &d in &features {
                let mut err_acc = 0.0f32;
                for trial in 0..trials {
                    let mut trng = rng.split(d as u64 * 1000 + trial);
                    let approx =
                        attention::approximate(method, &pr.q, &pr.k, &pr.v, d, &mut trng);
                    err_acc += norms::relative_spectral_error(&target, &approx);
                }
                cells.push(format!("{:.4}", err_acc / trials as f32));
            }
            t.row(cells);
        }
        println!("{}", t.render());
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn instability(args: &Args) -> Result<()> {
    let engine = Engine::new(artifacts_dir(args))?;
    let task = args.get_or("task", "listops").to_string();
    let attentions = args.get_list("attentions").unwrap_or_else(|| {
        vec!["kernelized".into(), "skyformer".into(), "nystromformer".into()]
    });
    let steps = args.get_usize("steps", 20)?;
    let lr = args.get_f32("lr", 1e-4)?;

    // baseline: self-attention
    let base_cfg = {
        let mut c = TrainConfig::new(&task, "softmax");
        c.seed = args.get_u64("seed", 0)?;
        c
    };
    let mut probe = InstabilityProbe::new(&engine, base_cfg)?;
    let base = probe.run(steps, lr)?;

    let mut t = Table::new(
        &format!("Table 3 (lite): instability-score ratio vs self-attention, task={task}"),
        &["model", "mean_tau", "ratio"],
    );
    t.row(vec![
        "softmax (baseline)".into(),
        format!("{:.4e}", base.mean_tau()),
        "1.00".into(),
    ]);
    for attn in attentions {
        let mut cfg = TrainConfig::new(&task, &attn);
        cfg.seed = args.get_u64("seed", 0)?;
        let mut probe = match InstabilityProbe::new(&engine, cfg) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("skip {attn}: {e}");
                continue;
            }
        };
        let r = probe.run(steps, lr)?;
        // paper: per-step ratio averaged over steps
        let ratio: f32 = r
            .taus
            .iter()
            .zip(&base.taus)
            .map(|(a, b)| a / b.max(1e-30))
            .sum::<f32>()
            / r.taus.len() as f32;
        t.row(vec![
            attn.clone(),
            format!("{:.4e}", r.mean_tau()),
            format!("{ratio:.2}"),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

#[cfg(feature = "pjrt")]
fn svd_cmd(args: &Args) -> Result<()> {
    let engine = Engine::new(artifacts_dir(args))?;
    let task = args.get_or("task", "listops").to_string();
    let attention = args.get_or("attention", "softmax").to_string();
    let steps = args.get_usize("steps", 100)?;

    // train briefly, then embed a test batch and report singular values
    let mut cfg = TrainConfig::new(&task, &attention);
    cfg.steps = steps;
    cfg.seed = args.get_u64("seed", 0)?;
    let mut trainer = Trainer::new(&engine, cfg)?;
    for s in 0..steps {
        trainer.step(s)?;
    }
    let exec_embed = engine.load(&task, &attention, "embed", false)?;
    let n_p = exec_embed.spec.num_params;
    let batch = trainer.dataset_batch(Split::Test, 0);
    let mut inputs: Vec<skyformer::runtime::tensor::Tensor> = trainer.state()[..n_p].to_vec();
    inputs.push(batch.tokens);
    inputs.push(skyformer::runtime::tensor::Tensor::scalar_u32(0));
    let out = exec_embed.run(&inputs)?;
    let emb = &out[0];
    let shape = emb.shape().to_vec();
    let m = Matrix {
        rows: shape[0],
        cols: shape[1],
        data: emb.as_f32()?.to_vec(),
    };
    let sv = svd::singular_values(&m);
    println!(
        "Figure 4 (lite): singular values of pooled attention output ({task}/{attention}, {steps} steps)"
    );
    let head = sv[0].max(1e-20);
    for (i, s) in sv.iter().enumerate() {
        println!("  sigma[{i:>2}] = {s:.5}   (ratio {:.4})", s / head);
    }
    Ok(())
}
