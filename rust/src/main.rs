//! `skyformer` — the Layer-3 coordinator CLI.
//!
//! Subcommands map one-to-one onto the paper's experiments (DESIGN.md §4):
//!
//! ```text
//! skyformer info                              # list built artifacts
//! skyformer train   --task listops --attention skyformer --steps 300
//! skyformer sweep   --tasks listops --attentions softmax,skyformer --seeds 3
//! skyformer approx  --n 256 --features 16,32,64,128,256    # Figure 1
//! skyformer instability --task listops                     # Table 3
//! skyformer svd     --task listops --attention softmax     # Figure 4
//! ```

#[cfg(feature = "pjrt")]
use std::path::PathBuf;

use skyformer::attention::{self, exact, probes};
#[cfg(feature = "pjrt")]
use skyformer::coordinator::instability::InstabilityProbe;
#[cfg(feature = "pjrt")]
use skyformer::coordinator::scheduler::Schedule;
#[cfg(feature = "pjrt")]
use skyformer::coordinator::trainer::{TrainConfig, Trainer};
#[cfg(feature = "pjrt")]
use skyformer::data::batch::Split;
#[cfg(feature = "pjrt")]
use skyformer::linalg::svd;
use skyformer::kernels::{self, KernelCtx};
#[cfg(feature = "pjrt")]
use skyformer::linalg::Matrix;
use skyformer::linalg::norms;
#[cfg(feature = "pjrt")]
use skyformer::report::tables::{fmt_bytes, fmt_secs};
use skyformer::report::tables::Table;
#[cfg(feature = "pjrt")]
use skyformer::runtime::engine::Engine;
use skyformer::util::args::Args;
use skyformer::util::rng::Rng;
use skyformer::Result;

fn main() {
    let args = Args::from_env();
    match args.get_usize("threads", 0) {
        Ok(0) => {}
        Ok(n) => kernels::set_threads(n),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
    if let Some(mode) = args.get("pool") {
        match skyformer::kernels::pool::Mode::parse(mode) {
            Some(m) => kernels::pool::set_mode(m),
            None => {
                eprintln!("error: bad --pool `{mode}` (scoped|pinned)");
                std::process::exit(1);
            }
        }
    }
    let env_prefix = skyformer::obs::init_from_env();
    let obs_out = args.get("obs-out").map(|s| s.to_string()).or(env_prefix);
    if obs_out.is_some() {
        skyformer::obs::set_enabled(true);
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match run(cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    if let Some(prefix) = obs_out {
        match skyformer::obs::dump(&prefix) {
            Ok(paths) => eprintln!("obs: wrote {}", paths.join(", ")),
            Err(e) => eprintln!("obs: dump failed: {e}"),
        }
    }
    std::process::exit(code);
}

#[cfg(feature = "pjrt")]
fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("artifacts", "artifacts"))
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        #[cfg(feature = "pjrt")]
        "info" => info(args),
        #[cfg(feature = "pjrt")]
        "train" => train(args),
        #[cfg(feature = "pjrt")]
        "sweep" => sweep(args),
        "approx" => approx(args),
        "kernels" => kernels_cmd(args),
        #[cfg(feature = "pjrt")]
        "instability" => instability(args),
        #[cfg(feature = "pjrt")]
        "svd" => svd_cmd(args),
        #[cfg(not(feature = "pjrt"))]
        "info" | "train" | "sweep" | "instability" | "svd" => Err(skyformer::Error::Config(
            format!("`{cmd}` needs PJRT: rebuild with `--features pjrt`"),
        )),
        _ => {
            print!("{HELP}");
            Ok(())
        }
    }
}

const HELP: &str = r#"skyformer — Skyformer (NeurIPS 2021) reproduction coordinator

USAGE: skyformer <command> [--flags]

COMMANDS
  info          list built artifacts and their configs
  train         train one (task, attention) model
                  --task listops --attention skyformer [--steps 200]
                  [--seed 0] [--lr 1e-4] [--eval-every 50] [--pallas]
                  [--checkpoint out.ckpt] [--verbose]
  sweep         Table 1/2: train a grid and print accuracy/time/memory rows
                  --tasks listops,text --attentions softmax,skyformer
                  [--seeds 1] [--steps 200] [--curves out.json]
  approx        Figure 1: spectral-norm error vs #features
                  [--n 256] [--features 16,32,64,128,256]
                  [--regimes init,pretrained] [--trials 3]
  kernels       exercise the native kernel subsystem on seeded inputs
                  [--n 96] [--p 16] [--seed 42]
                  [--digest]  print only `name digest` lines (stdout) for
                              the CI cross-thread determinism diff
  instability   Table 3: 20-step instability-score ratios vs self-attention
                  --task listops [--attentions kernelized,skyformer,nystromformer]
  svd           Figure 4: singular-value decay of attention output
                  --task listops --attention softmax [--steps 100]
GLOBAL
  --artifacts DIR   artifact directory (default: artifacts)
  --threads N       kernel pool width (wins over SKYFORMER_THREADS; the
                    determinism contract makes outputs bit-identical for
                    every N)
  --pool MODE       kernel pool backend, scoped|pinned (wins over
                    SKYFORMER_POOL; default pinned — persistent parked
                    workers; outputs are bit-identical in both modes)
  --obs-out PREFIX  dump observability sinks on exit: PREFIX.trace.json
                    (chrome://tracing), PREFIX.events.jsonl,
                    PREFIX.metrics.json, PREFIX.metrics.prom; implies tracing
ENV
  SKYFORMER_TRACE=1        enable span tracing
  SKYFORMER_OBS_OUT=PREFIX same as --obs-out (flag wins)
  SKYFORMER_THREADS=N      kernel pool width (default: available cores)
  SKYFORMER_POOL=MODE      kernel pool backend, scoped|pinned (default pinned)
"#;

/// `skyformer kernels`: run every kernel on seeded inputs and report
/// bit-pattern digests plus parity against the scalar oracles.  With
/// `--digest`, only `name digest` lines go to stdout (config goes to
/// stderr) so CI can diff runs at different `--threads` byte-for-byte.
fn kernels_cmd(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 96)?;
    let p = args.get_usize("p", 16)?;
    let ctx = KernelCtx::global();
    eprintln!(
        "kernels: n={n} p={p} threads={} pool={}",
        ctx.threads,
        ctx.mode.name()
    );

    // the suite lives in the library so the golden-fixture integration
    // test (rust/tests/golden.rs) exercises the exact same workload
    let outs = kernels::digest_suite(ctx, n, p, args.get_u64("seed", 42)?);

    if args.get_bool("digest") {
        for (name, out, _) in &outs {
            println!("{name} {:016x}", kernels::digest(out));
        }
        return Ok(());
    }

    let mut t = Table::new(
        &format!(
            "Kernel subsystem: n={n} p={p} threads={} pool={}",
            ctx.threads,
            ctx.mode.name()
        ),
        &["kernel", "shape", "digest", "scalar parity"],
    );
    let mut all_exact = true;
    for (name, out, want) in &outs {
        let exact = kernels::digest(out) == kernels::digest(want);
        all_exact &= exact;
        t.row(vec![
            name.to_string(),
            format!("{}x{}", out.rows, out.cols),
            format!("{:016x}", kernels::digest(out)),
            if exact { "bit-exact".into() } else { "DIVERGED".into() },
        ]);
    }
    println!("{}", t.render());
    if !all_exact {
        return Err(skyformer::Error::Config(
            "kernel output diverged from the scalar oracle".into(),
        ));
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn info(args: &Args) -> Result<()> {
    let engine = Engine::new(artifacts_dir(args))?;
    println!("platform: {}", engine.platform());
    let mut t = Table::new(
        "Artifacts",
        &["name", "kind", "task", "attention", "inputs", "outputs", "bytes"],
    );
    for (name, spec) in &engine.manifest().artifacts {
        t.row(vec![
            name.clone(),
            spec.kind.clone(),
            spec.task.clone(),
            spec.attention.clone(),
            spec.inputs.len().to_string(),
            spec.outputs.len().to_string(),
            fmt_bytes(spec.input_bytes()),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

#[cfg(feature = "pjrt")]
fn train_config_from(args: &Args) -> Result<TrainConfig> {
    let task = args.get_or("task", "listops").to_string();
    let attention = args.get_or("attention", "skyformer").to_string();
    let mut cfg = TrainConfig::new(&task, &attention);
    cfg.pallas = args.get_bool("pallas");
    cfg.steps = args.get_usize("steps", cfg.steps)?;
    cfg.eval_every = args.get_usize("eval-every", cfg.eval_every)?;
    cfg.eval_batches = args.get_usize("eval-batches", cfg.eval_batches)?;
    cfg.seed = args.get_u64("seed", 0)?;
    cfg.verbose = args.get_bool("verbose");
    if let Some(lr) = args.get("lr") {
        let lr: f32 = lr
            .parse()
            .map_err(|_| skyformer::Error::Config("bad --lr".into()))?;
        cfg.schedule = Schedule::Warmup { base: lr, warmup_steps: 20 };
    }
    cfg.checkpoint_path = args.get("checkpoint").map(PathBuf::from);
    Ok(cfg)
}

#[cfg(feature = "pjrt")]
fn train(args: &Args) -> Result<()> {
    let engine = Engine::new(artifacts_dir(args))?;
    let mut cfg = train_config_from(args)?;
    cfg.verbose = true;
    let mut trainer = Trainer::new(&engine, cfg)?;
    let result = trainer.train()?;
    println!(
        "done: best_eval_acc={:.4} test_acc={:.4} final_loss={:.4} time={} peak={}",
        result.best_eval_acc,
        result.test_acc,
        result.final_eval_loss,
        fmt_secs(result.total_seconds),
        fmt_bytes(result.metrics.peak_bytes),
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
fn sweep(args: &Args) -> Result<()> {
    let engine = Engine::new(artifacts_dir(args))?;
    let tasks = args
        .get_list("tasks")
        .unwrap_or_else(|| vec!["listops".into()]);
    let attentions = args.get_list("attentions").unwrap_or_else(|| {
        vec!["softmax".into(), "kernelized".into(), "skyformer".into()]
    });
    let seeds = args.get_u64("seeds", 1)?;
    let steps = args.get_usize("steps", 200)?;

    let mut acc_table = Table::new(
        "Table 1 (lite): classification accuracy (%)",
        &["model", "task", "test_acc", "best_eval_acc", "seeds"],
    );
    let mut cost_table = Table::new(
        "Table 2 (lite): per-step time and peak tensor memory",
        &["model", "task", "s/step", "total", "peak_mem"],
    );
    let mut curves: Vec<skyformer::util::json::Value> = Vec::new();

    for task in &tasks {
        for attn in &attentions {
            let mut accs = Vec::new();
            let mut best_accs = Vec::new();
            let mut step_secs = Vec::new();
            let mut totals = Vec::new();
            let mut peak = 0usize;
            for seed in 0..seeds {
                let mut cfg = train_config_from(args)?;
                cfg.task = task.clone();
                cfg.attention = attn.clone();
                cfg.steps = steps;
                cfg.seed = seed;
                let mut trainer = match Trainer::new(&engine, cfg) {
                    Ok(t) => t,
                    Err(e) => {
                        eprintln!("skip {task}/{attn}: {e}");
                        continue;
                    }
                };
                let r = trainer.train()?;
                eprintln!(
                    "{task}/{attn} seed {seed}: test {:.3} best {:.3} ({})",
                    r.test_acc,
                    r.best_eval_acc,
                    fmt_secs(r.total_seconds)
                );
                accs.push(r.test_acc);
                best_accs.push(r.best_eval_acc);
                step_secs.push(r.metrics.mean_step_seconds());
                totals.push(r.total_seconds);
                peak = peak.max(r.metrics.peak_bytes);
                curves.push(skyformer::util::json::obj(vec![
                    ("task", skyformer::util::json::s(task.clone())),
                    ("attention", skyformer::util::json::s(attn.clone())),
                    ("seed", skyformer::util::json::num(seed as f64)),
                    ("metrics", r.metrics.to_json()),
                ]));
            }
            if accs.is_empty() {
                continue;
            }
            let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len() as f32;
            let meand = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
            acc_table.row(vec![
                attn.clone(),
                task.clone(),
                format!("{:.2}", 100.0 * mean(&accs)),
                format!("{:.2}", 100.0 * mean(&best_accs)),
                accs.len().to_string(),
            ]);
            cost_table.row(vec![
                attn.clone(),
                task.clone(),
                format!("{:.3}", meand(&step_secs)),
                fmt_secs(meand(&totals)),
                fmt_bytes(peak),
            ]);
        }
    }
    println!("{}", acc_table.render());
    println!("{}", cost_table.render());
    if let Some(path) = args.get("curves") {
        let doc = skyformer::util::json::Value::Array(curves);
        std::fs::write(path, skyformer::util::json::to_string(&doc))?;
        println!("curves written to {path}");
    }
    Ok(())
}

fn approx(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 256)?;
    let p = args.get_usize("p", 32)?;
    let trials = args.get_u64("trials", 3)?;
    let features: Vec<usize> = args
        .get_list("features")
        .unwrap_or_else(|| vec!["16".into(), "32".into(), "64".into(), "128".into(), "256".into()])
        .iter()
        .map(|s| s.parse().unwrap_or(64))
        .collect();
    let regimes: Vec<probes::Regime> = args
        .get_list("regimes")
        .unwrap_or_else(|| vec!["init".into(), "pretrained".into()])
        .iter()
        .filter_map(|r| match r.as_str() {
            "init" => Some(probes::Regime::Init),
            "pretrained" => Some(probes::Regime::Pretrained),
            _ => None,
        })
        .collect();

    for regime in regimes {
        let mut headers = vec!["method".to_string()];
        headers.extend(features.iter().map(|f| format!("d={f}")));
        let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(
            &format!(
                "Figure 1 (lite): relative spectral error, n={n}, {} weights",
                regime.name()
            ),
            &header_refs,
        );
        let mut rng = Rng::new(args.get_u64("seed", 0)?).split_str(regime.name());
        let pr = probes::probe(regime, n, p, &mut rng);
        let target = exact::softmax_attention(&pr.q, &pr.k, &pr.v);
        for method in attention::METHODS {
            let mut cells = vec![method.name().to_string()];
            for &d in &features {
                let mut err_acc = 0.0f32;
                for trial in 0..trials {
                    let mut trng = rng.split(d as u64 * 1000 + trial);
                    let approx =
                        attention::approximate(method, &pr.q, &pr.k, &pr.v, d, &mut trng);
                    err_acc += norms::relative_spectral_error(&target, &approx);
                }
                cells.push(format!("{:.4}", err_acc / trials as f32));
            }
            t.row(cells);
        }
        println!("{}", t.render());
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn instability(args: &Args) -> Result<()> {
    let engine = Engine::new(artifacts_dir(args))?;
    let task = args.get_or("task", "listops").to_string();
    let attentions = args.get_list("attentions").unwrap_or_else(|| {
        vec!["kernelized".into(), "skyformer".into(), "nystromformer".into()]
    });
    let steps = args.get_usize("steps", 20)?;
    let lr = args.get_f32("lr", 1e-4)?;

    // baseline: self-attention
    let base_cfg = {
        let mut c = TrainConfig::new(&task, "softmax");
        c.seed = args.get_u64("seed", 0)?;
        c
    };
    let mut probe = InstabilityProbe::new(&engine, base_cfg)?;
    let base = probe.run(steps, lr)?;

    let mut t = Table::new(
        &format!("Table 3 (lite): instability-score ratio vs self-attention, task={task}"),
        &["model", "mean_tau", "ratio"],
    );
    t.row(vec![
        "softmax (baseline)".into(),
        format!("{:.4e}", base.mean_tau()),
        "1.00".into(),
    ]);
    for attn in attentions {
        let mut cfg = TrainConfig::new(&task, &attn);
        cfg.seed = args.get_u64("seed", 0)?;
        let mut probe = match InstabilityProbe::new(&engine, cfg) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("skip {attn}: {e}");
                continue;
            }
        };
        let r = probe.run(steps, lr)?;
        // paper: per-step ratio averaged over steps
        let ratio: f32 = r
            .taus
            .iter()
            .zip(&base.taus)
            .map(|(a, b)| a / b.max(1e-30))
            .sum::<f32>()
            / r.taus.len() as f32;
        t.row(vec![
            attn.clone(),
            format!("{:.4e}", r.mean_tau()),
            format!("{ratio:.2}"),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

#[cfg(feature = "pjrt")]
fn svd_cmd(args: &Args) -> Result<()> {
    let engine = Engine::new(artifacts_dir(args))?;
    let task = args.get_or("task", "listops").to_string();
    let attention = args.get_or("attention", "softmax").to_string();
    let steps = args.get_usize("steps", 100)?;

    // train briefly, then embed a test batch and report singular values
    let mut cfg = TrainConfig::new(&task, &attention);
    cfg.steps = steps;
    cfg.seed = args.get_u64("seed", 0)?;
    let mut trainer = Trainer::new(&engine, cfg)?;
    for s in 0..steps {
        trainer.step(s)?;
    }
    let exec_embed = engine.load(&task, &attention, "embed", false)?;
    let n_p = exec_embed.spec.num_params;
    let batch = trainer.dataset_batch(Split::Test, 0);
    let mut inputs: Vec<skyformer::runtime::tensor::Tensor> = trainer.state()[..n_p].to_vec();
    inputs.push(batch.tokens);
    inputs.push(skyformer::runtime::tensor::Tensor::scalar_u32(0));
    let out = exec_embed.run(&inputs)?;
    let emb = &out[0];
    let shape = emb.shape().to_vec();
    let m = Matrix {
        rows: shape[0],
        cols: shape[1],
        data: emb.as_f32()?.to_vec(),
    };
    let sv = svd::singular_values(&m);
    println!(
        "Figure 4 (lite): singular values of pooled attention output ({task}/{attention}, {steps} steps)"
    );
    let head = sv[0].max(1e-20);
    for (i, s) in sv.iter().enumerate() {
        println!("  sigma[{i:>2}] = {s:.5}   (ratio {:.4})", s / head);
    }
    Ok(())
}
