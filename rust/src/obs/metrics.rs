//! Global metrics registry: counters, gauges, and log-bucketed histograms,
//! exportable as JSON and as Prometheus text format.
//!
//! Metrics are always on (they are cheap relative to the step/solve
//! granularity they measure — one mutex lock plus a map lookup); span
//! *tracing* is the opt-in part of the obs layer.  Names are free-form
//! internally and sanitised on Prometheus export.

use std::collections::BTreeMap;
use std::sync::{Mutex, MutexGuard, OnceLock};

use crate::util::json::{self, Value};

/// Number of histogram buckets. Bucket `i < NUM_BUCKETS - 1` covers values
/// `<= bucket_bound(i)`; the last bucket is the +Inf overflow.
pub const NUM_BUCKETS: usize = 64;

/// Upper bound of bucket `i`: `1e-9 * 2^i` — 1 ns up to ~2.9 centuries
/// when values are seconds, with log2 resolution everywhere between.
pub fn bucket_bound(i: usize) -> f64 {
    1e-9 * 2f64.powi(i as i32)
}

/// Index of the bucket a value lands in (non-positive and NaN values are
/// clamped into bucket 0).
pub fn bucket_index(v: f64) -> usize {
    if v.is_nan() || v <= 0.0 {
        return 0;
    }
    for i in 0..NUM_BUCKETS - 1 {
        if v <= bucket_bound(i) {
            return i;
        }
    }
    NUM_BUCKETS - 1
}

/// Log-bucketed histogram with sum/count/min/max.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
    pub buckets: Vec<u64>,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            buckets: vec![0; NUM_BUCKETS],
        }
    }
}

impl Histogram {
    pub fn observe(&mut self, v: f64) {
        self.buckets[bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

/// One registered metric.
#[derive(Debug, Clone, PartialEq)]
pub enum Metric {
    Counter(u64),
    Gauge(f64),
    Histogram(Histogram),
}

/// A snapshot (or the live registry) of every metric, keyed by name.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    pub metrics: BTreeMap<String, Metric>,
    /// Updates that hit an existing metric of a different type (ignored
    /// rather than corrupting — never silent).
    pub type_conflicts: u64,
}

impl Registry {
    fn counter_add(&mut self, name: &str, delta: u64) {
        match self.metrics.get_mut(name) {
            Some(Metric::Counter(c)) => *c += delta,
            Some(_) => self.type_conflicts += 1,
            None => {
                self.metrics.insert(name.to_string(), Metric::Counter(delta));
            }
        }
    }

    fn gauge_set(&mut self, name: &str, v: f64) {
        match self.metrics.get_mut(name) {
            Some(Metric::Gauge(g)) => *g = v,
            Some(_) => self.type_conflicts += 1,
            None => {
                self.metrics.insert(name.to_string(), Metric::Gauge(v));
            }
        }
    }

    fn observe(&mut self, name: &str, v: f64) {
        match self.metrics.get_mut(name) {
            Some(Metric::Histogram(h)) => h.observe(v),
            Some(_) => self.type_conflicts += 1,
            None => {
                let mut h = Histogram::default();
                h.observe(v);
                self.metrics.insert(name.to_string(), Metric::Histogram(h));
            }
        }
    }

    /// JSON export (used by `--obs-out` and the bench artifacts).
    pub fn to_json(&self) -> Value {
        let mut counters = Vec::new();
        let mut gauges = Vec::new();
        let mut histograms = Vec::new();
        for (name, m) in &self.metrics {
            match m {
                Metric::Counter(c) => counters.push((name.as_str(), json::num(*c as f64))),
                Metric::Gauge(g) => gauges.push((name.as_str(), json::num(*g))),
                Metric::Histogram(h) => {
                    let buckets: Vec<Value> = h
                        .buckets
                        .iter()
                        .enumerate()
                        .filter(|(_, &c)| c > 0)
                        .map(|(i, &c)| {
                            json::obj(vec![
                                (
                                    "le",
                                    if i + 1 == NUM_BUCKETS {
                                        json::s("+Inf")
                                    } else {
                                        json::num(bucket_bound(i))
                                    },
                                ),
                                ("count", json::num(c as f64)),
                            ])
                        })
                        .collect();
                    histograms.push((
                        name.as_str(),
                        json::obj(vec![
                            ("count", json::num(h.count as f64)),
                            ("sum", json::num(h.sum)),
                            ("min", json::num(if h.count == 0 { 0.0 } else { h.min })),
                            ("max", json::num(if h.count == 0 { 0.0 } else { h.max })),
                            ("mean", json::num(h.mean())),
                            ("buckets", Value::Array(buckets)),
                        ]),
                    ));
                }
            }
        }
        json::obj(vec![
            ("counters", json::obj(counters)),
            ("gauges", json::obj(gauges)),
            ("histograms", json::obj(histograms)),
            ("type_conflicts", json::num(self.type_conflicts as f64)),
        ])
    }

    /// Prometheus text exposition format.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, m) in &self.metrics {
            let n = sanitize_name(name);
            match m {
                Metric::Counter(c) => {
                    out.push_str(&format!("# TYPE {n} counter\n{n} {c}\n"));
                }
                Metric::Gauge(g) => {
                    out.push_str(&format!("# TYPE {n} gauge\n{n} {}\n", fmt_value(*g)));
                }
                Metric::Histogram(h) => {
                    out.push_str(&format!("# TYPE {n} histogram\n"));
                    let mut cumulative = 0u64;
                    let last_nonzero = h
                        .buckets
                        .iter()
                        .rposition(|&c| c > 0)
                        .unwrap_or(0)
                        .min(NUM_BUCKETS - 2);
                    for (i, &c) in h.buckets.iter().enumerate().take(last_nonzero + 1) {
                        cumulative += c;
                        if c > 0 {
                            out.push_str(&format!(
                                "{n}_bucket{{le=\"{}\"}} {cumulative}\n",
                                fmt_value(bucket_bound(i))
                            ));
                        }
                    }
                    out.push_str(&format!("{n}_bucket{{le=\"+Inf\"}} {}\n", h.count));
                    out.push_str(&format!("{n}_sum {}\n", fmt_value(h.sum)));
                    out.push_str(&format!("{n}_count {}\n", h.count));
                }
            }
        }
        out
    }
}

/// Map an arbitrary metric name onto the Prometheus charset
/// `[a-zA-Z_:][a-zA-Z0-9_:]*`.  Every disallowed char becomes `_`; a
/// leading digit gets a `_` prefix; empty names become `_`.
pub fn sanitize_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    for c in name.chars() {
        if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
            out.push(c);
        } else {
            out.push('_');
        }
    }
    if out.is_empty() {
        out.push('_');
    }
    if out.as_bytes()[0].is_ascii_digit() {
        out.insert(0, '_');
    }
    out
}

fn fmt_value(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

fn global() -> MutexGuard<'static, Registry> {
    static REGISTRY: OnceLock<Mutex<Registry>> = OnceLock::new();
    REGISTRY
        .get_or_init(|| Mutex::new(Registry::default()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

/// Add `delta` to counter `name` (created on first use).
pub fn counter_add(name: &str, delta: u64) {
    global().counter_add(name, delta);
}

/// Set gauge `name` to `v` (created on first use).
pub fn gauge_set(name: &str, v: f64) {
    global().gauge_set(name, v);
}

/// Record `v` into histogram `name` (created on first use).
pub fn observe(name: &str, v: f64) {
    global().observe(name, v);
}

/// Clone the current registry state.
pub fn snapshot() -> Registry {
    global().clone()
}

/// Clear every metric (fresh runs in one process; tests).
pub fn reset() {
    let mut g = global();
    g.metrics.clear();
    g.type_conflicts = 0;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_inclusive_log2() {
        // exact boundary lands in its own bucket; epsilon above moves up
        assert_eq!(bucket_index(1e-9), 0);
        assert_eq!(bucket_index(2e-9), 1);
        assert_eq!(bucket_index(2.0000001e-9), 2);
        assert_eq!(bucket_index(1.0), bucket_index(bucket_bound(bucket_index(1.0))));
        // monotone in v
        let mut prev = 0;
        for k in 0..40 {
            let idx = bucket_index(1e-9 * 1.9f64.powi(k));
            assert!(idx >= prev);
            prev = idx;
        }
        // clamps
        assert_eq!(bucket_index(0.0), 0);
        assert_eq!(bucket_index(-5.0), 0);
        assert_eq!(bucket_index(f64::NAN), 0);
        assert_eq!(bucket_index(1e300), NUM_BUCKETS - 1);
    }

    #[test]
    fn histogram_accumulates() {
        let mut h = Histogram::default();
        for v in [0.001, 0.002, 0.004, 4000.0] {
            h.observe(v);
        }
        assert_eq!(h.count, 4);
        assert!((h.sum - 4000.007).abs() < 1e-9);
        assert_eq!(h.min, 0.001);
        assert_eq!(h.max, 4000.0);
        assert_eq!(h.buckets.iter().sum::<u64>(), 4);
    }

    #[test]
    fn registry_local_roundtrip_json() {
        let mut r = Registry::default();
        r.counter_add("steps_total", 3);
        r.gauge_set("loss", 1.25);
        r.observe("step_seconds", 0.01);
        r.observe("step_seconds", 0.02);
        let v = r.to_json();
        let text = json::to_string(&v);
        let back = json::parse(&text).unwrap();
        assert_eq!(
            back.get("counters").unwrap().get("steps_total").unwrap().as_f64(),
            Some(3.0)
        );
        assert_eq!(back.get("gauges").unwrap().get("loss").unwrap().as_f64(), Some(1.25));
        let h = back.get("histograms").unwrap().get("step_seconds").unwrap();
        assert_eq!(h.get("count").unwrap().as_f64(), Some(2.0));
        assert!(!h.get("buckets").unwrap().as_array().unwrap().is_empty());
    }

    #[test]
    fn type_conflicts_do_not_corrupt() {
        let mut r = Registry::default();
        r.counter_add("x", 1);
        r.gauge_set("x", 9.0); // wrong type: ignored, counted
        r.observe("x", 1.0); // wrong type: ignored, counted
        assert_eq!(r.metrics.get("x"), Some(&Metric::Counter(1)));
        assert_eq!(r.type_conflicts, 2);
    }

    #[test]
    fn prometheus_text_shape() {
        let mut r = Registry::default();
        r.counter_add("train steps (total)", 7);
        r.observe("step_seconds", 0.5);
        r.observe("step_seconds", 1e9); // overflow bucket
        let text = r.to_prometheus();
        assert!(text.contains("# TYPE step_seconds histogram"), "{text}");
        assert!(text.contains("step_seconds_bucket{le=\"+Inf\"} 2"), "{text}");
        assert!(text.contains("step_seconds_count 2"), "{text}");
        // spaces/parens sanitised
        assert!(text.contains("train_steps__total_ 7"), "{text}");
        // cumulative: the 0.5 bucket count is 1
        let line = text
            .lines()
            .find(|l| l.starts_with("step_seconds_bucket") && !l.contains("+Inf"))
            .unwrap();
        assert!(line.ends_with(" 1"), "{line}");
    }

    #[test]
    fn sanitize_covers_edge_cases() {
        assert_eq!(sanitize_name("ok_name:v1"), "ok_name:v1");
        assert_eq!(sanitize_name("9lives"), "_9lives");
        assert_eq!(sanitize_name(""), "_");
        assert_eq!(sanitize_name("a b\nc\"d"), "a_b_c_d");
        assert_eq!(sanitize_name("é😀"), "__");
    }

    #[test]
    fn global_registry_api() {
        counter_add("test_metrics_global_counter", 2);
        counter_add("test_metrics_global_counter", 3);
        gauge_set("test_metrics_global_gauge", -1.5);
        observe("test_metrics_global_hist", 0.25);
        let snap = snapshot();
        assert_eq!(
            snap.metrics.get("test_metrics_global_counter"),
            Some(&Metric::Counter(5))
        );
        assert_eq!(
            snap.metrics.get("test_metrics_global_gauge"),
            Some(&Metric::Gauge(-1.5))
        );
        match snap.metrics.get("test_metrics_global_hist") {
            Some(Metric::Histogram(h)) => assert!(h.count >= 1),
            other => panic!("expected histogram, got {other:?}"),
        }
    }
}
