//! Observability: span tracing, metrics, and export sinks.
//!
//! Three pieces, zero external dependencies:
//!
//! * [`span`] — hierarchical scoped timers ([`span::span`] returns an RAII
//!   guard) plus instant events, buffered in-process.  Disabled by default;
//!   a disabled span costs one relaxed atomic load.
//! * [`metrics`] — global registry of counters, gauges, and log-bucketed
//!   histograms; always on.
//! * [`export`] — Chrome Trace Event Format (`chrome://tracing` /
//!   Perfetto), structured JSONL, metrics as JSON and Prometheus text.
//!
//! Environment knobs (read by [`init_from_env`]):
//!
//! * `SKYFORMER_TRACE=1` — enable span tracing.
//! * `SKYFORMER_OBS_OUT=<prefix>` — on [`finish`], dump all sinks as
//!   `<prefix>.trace.json`, `<prefix>.events.jsonl`, `<prefix>.metrics.json`,
//!   `<prefix>.metrics.prom`.  Implies tracing on.
//!
//! Binaries also take `--obs-out <prefix>`, which overrides the env var.
//!
//! Typical wiring (see `coordinator::trainer`, `runtime::engine`):
//!
//! ```
//! use skyformer::obs;
//! obs::set_enabled(true);
//! {
//!     let _step = obs::span("train", "step");
//!     obs::observe("step_seconds", 0.012);
//! } // span recorded here
//! let trace = obs::export::chrome_trace(&obs::snapshot_events());
//! assert!(!trace.get("traceEvents").unwrap().as_array().unwrap().is_empty());
//! # obs::set_enabled(false);
//! ```

pub mod export;
pub mod metrics;
pub mod span;

pub use export::dump;
pub use metrics::{counter_add, gauge_set, observe, snapshot, Metric, Registry};
pub use span::{
    dropped_events, enabled, event, set_enabled, snapshot_events, span, SpanGuard, TraceEvent,
};

/// Read the `SKYFORMER_TRACE` / `SKYFORMER_OBS_OUT` knobs and turn tracing
/// on if either asks for it.  Returns the dump prefix from the env, if any.
pub fn init_from_env() -> Option<String> {
    let out = std::env::var("SKYFORMER_OBS_OUT").ok().filter(|s| !s.is_empty());
    let trace_on = std::env::var("SKYFORMER_TRACE")
        .map(|v| matches!(v.trim(), "1" | "true" | "yes" | "on"))
        .unwrap_or(false);
    if trace_on || out.is_some() {
        set_enabled(true);
    }
    out
}

/// Dump every sink to `prefix` (CLI `--obs-out` wins over the env var).
/// No-op when neither is set.  Returns the paths written.
pub fn finish(cli_prefix: Option<&str>) -> crate::util::error::Result<Vec<String>> {
    let env_prefix = std::env::var("SKYFORMER_OBS_OUT").ok().filter(|s| !s.is_empty());
    let prefix = match (cli_prefix, env_prefix) {
        (Some(p), _) => p.to_string(),
        (None, Some(p)) => p,
        (None, None) => return Ok(Vec::new()),
    };
    dump(&prefix)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finish_without_config_is_noop() {
        // no CLI prefix; env may not be set in the test environment —
        // only assert the no-CLI/no-env path
        if std::env::var("SKYFORMER_OBS_OUT").is_err() {
            assert!(finish(None).unwrap().is_empty());
        }
    }
}
