//! Hierarchical span tracing with RAII scoped timers.
//!
//! A [`span`] call returns a [`SpanGuard`]; dropping it records a
//! *complete* trace event (name, category, start, duration, thread).
//! Nesting is positional: chrome://tracing and the JSONL consumers infer
//! parent/child from timestamp containment on the same thread, so no
//! explicit span ids are needed.
//!
//! Cost model: when tracing is disabled (the default) a span is one
//! relaxed atomic load and no allocation — cheap enough for the
//! coordinator hot path (see `benches/coordinator_hotpath.rs`, §Perf
//! target ≤ 2% overhead).  When enabled, each span is a clock read at
//! open, and a clock read plus one bounded `Vec` push under a mutex at
//! close.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Instant;

use crate::util::json::Value;

/// Hard cap on buffered events so runaway loops cannot exhaust memory.
/// Overflow is counted (never silent) — see [`dropped_events`].
pub const MAX_EVENTS: usize = 1 << 20;

static ENABLED: AtomicBool = AtomicBool::new(false);
static DROPPED: AtomicU64 = AtomicU64::new(0);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn events() -> &'static Mutex<Vec<TraceEvent>> {
    static EVENTS: OnceLock<Mutex<Vec<TraceEvent>>> = OnceLock::new();
    EVENTS.get_or_init(|| Mutex::new(Vec::new()))
}

fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

fn current_tid() -> u64 {
    TID.with(|t| *t)
}

/// Is tracing currently on? One relaxed atomic load.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn tracing on/off process-wide.
pub fn set_enabled(on: bool) {
    // pin the epoch before the first span so timestamps start near zero
    let _ = epoch();
    ENABLED.store(on, Ordering::Relaxed);
}

/// Event phase, mirroring the Chrome Trace Event Format phases we emit.
#[derive(Debug, Clone, PartialEq)]
pub enum Phase {
    /// A closed span: `ph: "X"` with a duration.
    Complete { dur_ns: u64 },
    /// A point-in-time event: `ph: "i"` (anomalies, convergence marks).
    Instant,
}

/// One recorded trace event.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub name: String,
    pub cat: &'static str,
    pub phase: Phase,
    /// Nanoseconds since the process trace epoch.
    pub ts_ns: u64,
    pub tid: u64,
    /// Structured payload (JSON object) — convergence residuals, anomaly
    /// details, etc.
    pub args: Option<Value>,
}

fn record(ev: TraceEvent) {
    let mut buf = lock_events();
    if buf.len() >= MAX_EVENTS {
        DROPPED.fetch_add(1, Ordering::Relaxed);
        return;
    }
    buf.push(ev);
}

fn lock_events() -> MutexGuard<'static, Vec<TraceEvent>> {
    // a poisoned buffer only ever holds trace data; keep collecting
    events().lock().unwrap_or_else(|p| p.into_inner())
}

/// RAII scoped timer: records a complete span on drop.
#[must_use = "a span measures the scope it lives in; binding to `_g` keeps it open"]
pub struct SpanGuard(Option<ActiveSpan>);

struct ActiveSpan {
    name: String,
    cat: &'static str,
    start_ns: u64,
    start: Instant,
    args: Option<Value>,
}

impl SpanGuard {
    /// End the span now (before scope exit).
    pub fn done(self) {}

    /// Attach a JSON-object payload to the span (recorded at close).
    pub fn with_args(mut self, args: Value) -> SpanGuard {
        if let Some(a) = self.0.as_mut() {
            a.args = Some(args);
        }
        self
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(a) = self.0.take() {
            record(TraceEvent {
                name: a.name,
                cat: a.cat,
                phase: Phase::Complete { dur_ns: a.start.elapsed().as_nanos() as u64 },
                ts_ns: a.start_ns,
                tid: current_tid(),
                args: a.args,
            });
        }
    }
}

/// Open a span under `cat`; the returned guard closes it on drop.
/// No-op (and allocation-free) while tracing is disabled.
#[inline]
pub fn span(cat: &'static str, name: &str) -> SpanGuard {
    if !enabled() {
        return SpanGuard(None);
    }
    SpanGuard(Some(ActiveSpan {
        name: name.to_string(),
        cat,
        start_ns: now_ns(),
        start: Instant::now(),
        args: None,
    }))
}

/// Record an instant event (anomaly, convergence mark). No-op while
/// tracing is disabled.
pub fn event(cat: &'static str, name: &str, args: Option<Value>) {
    if !enabled() {
        return;
    }
    record(TraceEvent {
        name: name.to_string(),
        cat,
        phase: Phase::Instant,
        ts_ns: now_ns(),
        tid: current_tid(),
        args,
    });
}

/// Copy of every buffered event (export path — non-destructive).
pub fn snapshot_events() -> Vec<TraceEvent> {
    lock_events().clone()
}

/// Drain the buffer, returning everything collected so far.
pub fn drain_events() -> Vec<TraceEvent> {
    std::mem::take(&mut *lock_events())
}

/// Number of events dropped at the [`MAX_EVENTS`] cap.
pub fn dropped_events() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

/// Serialises tests that toggle the global enabled flag.
#[doc(hidden)]
pub fn test_lock() -> &'static Mutex<()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn guard() -> MutexGuard<'static, ()> {
        test_lock().lock().unwrap_or_else(|p| p.into_inner())
    }

    fn spans_in(cat: &'static str) -> Vec<TraceEvent> {
        snapshot_events().into_iter().filter(|e| e.cat == cat).collect()
    }

    #[test]
    fn disabled_span_records_nothing() {
        let _g = guard();
        set_enabled(false);
        {
            let _s = span("test_disabled", "noop");
        }
        event("test_disabled", "noop", None);
        assert!(spans_in("test_disabled").is_empty());
        set_enabled(true);
    }

    #[test]
    fn nested_spans_child_within_parent() {
        let _g = guard();
        set_enabled(true);
        {
            let _parent = span("test_nest", "parent");
            std::thread::sleep(std::time::Duration::from_millis(2));
            {
                let _child = span("test_nest", "child");
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let evs = spans_in("test_nest");
        let parent = evs.iter().find(|e| e.name == "parent").expect("parent");
        let child = evs.iter().find(|e| e.name == "child").expect("child");
        let (Phase::Complete { dur_ns: pd }, Phase::Complete { dur_ns: cd }) =
            (&parent.phase, &child.phase)
        else {
            panic!("spans must be complete events");
        };
        // timing invariants: child starts after parent, fits inside it
        assert!(child.ts_ns >= parent.ts_ns);
        assert!(cd <= pd, "child {cd}ns > parent {pd}ns");
        assert!(child.ts_ns + cd <= parent.ts_ns + pd);
        assert_eq!(child.tid, parent.tid);
    }

    #[test]
    fn instant_events_carry_args() {
        let _g = guard();
        set_enabled(true);
        let args = crate::util::json::obj(vec![("iter", crate::util::json::num(3.0))]);
        event("test_instant", "mark", Some(args.clone()));
        let evs = spans_in("test_instant");
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].phase, Phase::Instant);
        assert_eq!(evs[0].args.as_ref().unwrap().get("iter").unwrap().as_f64(), Some(3.0));
    }
}
