//! Sinks for the obs layer: Chrome Trace Event Format, structured JSONL,
//! and a combined `dump` that writes trace + events + metrics (JSON and
//! Prometheus text) under one path prefix.
//!
//! The Chrome trace can be loaded directly in `chrome://tracing` or
//! <https://ui.perfetto.dev>; nested spans render as stacked bars per
//! thread lane.

use std::io::Write as _;
use std::path::Path;

use crate::obs::metrics;
use crate::obs::span::{self, Phase, TraceEvent};
use crate::util::error::Result;
use crate::util::json::{self, Value};

/// One event as a Chrome Trace Event Format object.
///
/// Complete spans use `ph: "X"` (ts + dur, microseconds); instant events
/// use `ph: "i"` with thread scope.
pub fn event_to_json(ev: &TraceEvent) -> Value {
    let mut fields = vec![
        ("name", json::s(ev.name.as_str())),
        ("cat", json::s(ev.cat)),
        ("pid", json::num(1.0)),
        ("tid", json::num(ev.tid as f64)),
        ("ts", json::num(ev.ts_ns as f64 / 1e3)),
    ];
    match &ev.phase {
        Phase::Complete { dur_ns } => {
            fields.push(("ph", json::s("X")));
            fields.push(("dur", json::num(*dur_ns as f64 / 1e3)));
        }
        Phase::Instant => {
            fields.push(("ph", json::s("i")));
            fields.push(("s", json::s("t")));
        }
    }
    if let Some(args) = &ev.args {
        fields.push(("args", args.clone()));
    }
    json::obj(fields)
}

/// Build the full `{"traceEvents": [...]}` document from a snapshot of
/// the event buffer.
pub fn chrome_trace(events: &[TraceEvent]) -> Value {
    let evs: Vec<Value> = events.iter().map(event_to_json).collect();
    let mut fields = vec![
        ("traceEvents", Value::Array(evs)),
        ("displayTimeUnit", json::s("ms")),
    ];
    let dropped = span::dropped_events();
    if dropped > 0 {
        fields.push(("droppedEvents", json::num(dropped as f64)));
    }
    json::obj(fields)
}

/// Serialize events one-JSON-object-per-line (structured event log).
pub fn to_jsonl(events: &[TraceEvent]) -> String {
    let mut out = String::new();
    for ev in events {
        out.push_str(&json::to_string(&event_to_json(ev)));
        out.push('\n');
    }
    out
}

pub fn write_chrome_trace(path: impl AsRef<Path>, events: &[TraceEvent]) -> Result<()> {
    write_text(path, &json::to_string(&chrome_trace(events)))
}

pub fn write_jsonl(path: impl AsRef<Path>, events: &[TraceEvent]) -> Result<()> {
    write_text(path, &to_jsonl(events))
}

fn write_text(path: impl AsRef<Path>, text: &str) -> Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(text.as_bytes())?;
    Ok(())
}

/// Write every sink under one prefix:
/// `<prefix>.trace.json`, `<prefix>.events.jsonl`,
/// `<prefix>.metrics.json`, `<prefix>.metrics.prom`.
/// Returns the paths written.
pub fn dump(prefix: &str) -> Result<Vec<String>> {
    let events = span::snapshot_events();
    let registry = metrics::snapshot();
    let paths = vec![
        format!("{prefix}.trace.json"),
        format!("{prefix}.events.jsonl"),
        format!("{prefix}.metrics.json"),
        format!("{prefix}.metrics.prom"),
    ];
    write_chrome_trace(&paths[0], &events)?;
    write_jsonl(&paths[1], &events)?;
    write_text(&paths[2], &json::to_string(&registry.to_json()))?;
    write_text(&paths[3], &registry.to_prometheus())?;
    Ok(paths)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events() -> Vec<TraceEvent> {
        vec![
            TraceEvent {
                name: "step".into(),
                cat: "train",
                phase: Phase::Complete { dur_ns: 12_500 },
                ts_ns: 1_000,
                tid: 1,
                args: None,
            },
            TraceEvent {
                name: "upload".into(),
                cat: "runtime",
                phase: Phase::Complete { dur_ns: 2_000 },
                ts_ns: 1_500,
                tid: 1,
                args: Some(json::obj(vec![("bytes", json::num(4096.0))])),
            },
            TraceEvent {
                name: "anomaly".into(),
                cat: "instability",
                phase: Phase::Instant,
                ts_ns: 9_000,
                tid: 2,
                args: Some(json::obj(vec![("tau", json::num(0.5))])),
            },
        ]
    }

    #[test]
    fn chrome_trace_roundtrips_and_nests() {
        let evs = sample_events();
        let text = json::to_string(&chrome_trace(&evs));
        let doc = json::parse(&text).unwrap();
        let arr = doc.get("traceEvents").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);

        let step = &arr[0];
        assert_eq!(step.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(step.get("ts").unwrap().as_f64(), Some(1.0)); // 1000 ns = 1 µs
        assert_eq!(step.get("dur").unwrap().as_f64(), Some(12.5));

        // child (upload) contained within parent (step) in µs space
        let upload = &arr[1];
        let (pts, pdur) = (
            step.get("ts").unwrap().as_f64().unwrap(),
            step.get("dur").unwrap().as_f64().unwrap(),
        );
        let (cts, cdur) = (
            upload.get("ts").unwrap().as_f64().unwrap(),
            upload.get("dur").unwrap().as_f64().unwrap(),
        );
        assert!(cts >= pts && cts + cdur <= pts + pdur);
        assert_eq!(upload.get("args").unwrap().get("bytes").unwrap().as_f64(), Some(4096.0));

        let instant = &arr[2];
        assert_eq!(instant.get("ph").unwrap().as_str(), Some("i"));
        assert_eq!(instant.get("s").unwrap().as_str(), Some("t"));
        assert!(instant.get("dur").is_none());
    }

    #[test]
    fn jsonl_one_valid_object_per_line() {
        let text = to_jsonl(&sample_events());
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        for line in lines {
            let v = json::parse(line).unwrap();
            assert!(v.get("name").is_some());
            assert!(v.get("ts").is_some());
        }
    }

    #[test]
    fn dump_writes_all_four_sinks() {
        let dir = std::env::temp_dir().join("skyformer_obs_export_test");
        let prefix = dir.join("run").to_string_lossy().into_owned();
        let paths = dump(&prefix).unwrap();
        assert_eq!(paths.len(), 4);
        for p in &paths {
            let text = std::fs::read_to_string(p).unwrap();
            if p.ends_with(".trace.json") {
                let doc = json::parse(&text).unwrap();
                assert!(doc.get("traceEvents").is_some());
            } else if p.ends_with(".metrics.json") {
                assert!(json::parse(&text).is_ok());
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
