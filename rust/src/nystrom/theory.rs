//! Empirical checks of Theorem 2's quantities: leverage scores, statistical
//! dimension, and the lambda = eps ||C|| error bound.  Used by the
//! `theorem2_bound` bench and the property tests.

use crate::linalg::{solve, Matrix};

/// Ridge leverage scores `l_i = [C_bar (C_bar + lambda I)^{-1}]_ii` and the
/// statistical dimension `d_stat = sum_i l_i = Tr(C_bar (C_bar+lambda I)^{-1})`.
pub struct LeverageProfile {
    pub scores: Vec<f32>,
    pub d_stat: f32,
    pub lambda: f32,
}

/// Compute the profile for a PSD matrix `c_bar` at regularisation `lambda`.
pub fn leverage_profile(c_bar: &Matrix, lambda: f32) -> LeverageProfile {
    assert_eq!(c_bar.rows, c_bar.cols);
    let n = c_bar.rows;
    let reg = c_bar.add_diag(lambda);
    let inv = solve::gauss_jordan_inverse(&reg)
        .unwrap_or_else(|| solve::ns_inverse(c_bar, lambda, 30));
    let prod = c_bar.matmul(&inv);
    let scores: Vec<f32> = (0..n).map(|i| prod[(i, i)].clamp(0.0, 1.0)).collect();
    let d_stat = scores.iter().sum();
    LeverageProfile { scores, d_stat, lambda }
}

/// Theorem 2's coherence constant beta: the largest beta with
/// `beta <= d_stat / (2n * l_i)` for all i — i.e.
/// `beta = d_stat / (2n * max_i l_i)`.
pub fn coherence_beta(profile: &LeverageProfile) -> f32 {
    let max_l = profile
        .scores
        .iter()
        .fold(0.0f32, |m, &l| m.max(l))
        .max(1e-12);
    profile.d_stat / (profile.scores.len() as f32 * max_l)
}

/// Theorem 2's sufficient landmark count `d >= C (d_stat / beta) log(n / delta)`
/// with the lemma's C = 28/3 and delta = 0.1.
pub fn sufficient_landmarks(profile: &LeverageProfile) -> usize {
    let n = profile.scores.len() as f32;
    let beta = coherence_beta(profile);
    let c = 28.0 / 3.0;
    (c * profile.d_stat / beta * (n / 0.1).ln()).ceil() as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nystrom::{kernel_matrix, Kernel};
    use crate::util::rng::Rng;

    fn lifted(seed: u64, n: usize, p: usize, scale: f32) -> Matrix {
        let mut rng = Rng::new(seed);
        let q = Matrix::randn(&mut rng, n, p, scale);
        let k = Matrix::randn(&mut rng, n, p, scale);
        let x = q.vcat(&k);
        kernel_matrix(Kernel::Gaussian, &x, &x)
    }

    #[test]
    fn leverage_scores_in_unit_interval_and_dstat_sane() {
        let c_bar = lifted(0, 32, 8, 0.5);
        let prof = leverage_profile(&c_bar, 0.1);
        assert!(prof.scores.iter().all(|&l| (0.0..=1.0).contains(&l)));
        // d_stat <= rank <= 2n, and > 0
        assert!(prof.d_stat > 0.0 && prof.d_stat <= 64.0);
    }

    #[test]
    fn dstat_decreases_with_lambda() {
        let c_bar = lifted(1, 32, 8, 0.5);
        let d1 = leverage_profile(&c_bar, 0.01).d_stat;
        let d2 = leverage_profile(&c_bar, 0.1).d_stat;
        let d3 = leverage_profile(&c_bar, 1.0).d_stat;
        assert!(d1 > d2 && d2 > d3, "{d1} {d2} {d3}");
    }

    #[test]
    fn beta_at_most_one() {
        let c_bar = lifted(2, 24, 8, 0.5);
        let prof = leverage_profile(&c_bar, 0.05);
        let beta = coherence_beta(&prof);
        assert!(beta > 0.0 && beta <= 1.0 + 1e-4, "beta {beta}");
    }
}
