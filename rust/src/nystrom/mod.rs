//! Native-rust modified Nyström method (paper §4.2) on the dense substrate.
//!
//! The twin of the L1 Pallas implementation, used where the study needs
//! materialised matrices (Figure 1, Theorem-2 empirics, property tests):
//!
//! 1. lift the asymmetric empirical kernel matrix `B = phi(Q, K)` into the
//!    PSD completion `B_bar = phi([Q;K], [Q;K])` (Eq. 4);
//! 2. uniform-subsample d of the 2n rows (Definition 1);
//! 3. `B_tilde_bar = B_bar S (S^T B_bar S)^+ S^T B_bar` (Eq. 5);
//! 4. read off the top-right n x n block (Eq. 6).
//!
//! The pseudo-inverse is either exact (Gauss–Jordan on CPU — the paper's
//! "matrix inversion on CPU" reference point) or the preconditioned
//! Newton–Schulz iteration (§4.4).

pub mod theory;

use crate::linalg::{solve, Matrix};
use crate::obs;
use crate::util::rng::Rng;

/// PSD kernel functions the paper uses.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Kernel {
    /// `kappa(x, y) = exp(-||x - y||^2 / 2)` on pre-scaled inputs
    /// (bandwidth p^{1/4} folded into the scaling).
    Gaussian,
    /// `SM(x, y) = exp(x . y)` on pre-scaled inputs (the softmax kernel).
    Softmax,
}

impl Kernel {
    /// One kernel entry, reduced through the same `tile::dot` /
    /// `tile::half_sq_norm` lane order and combined with the same
    /// `(dot - hx - hy).exp()` expression as the fused score kernels —
    /// so a scalar `eval` is bit-identical to the matching
    /// [`kernel_matrix`] entry.
    #[inline]
    pub fn eval(&self, x: &[f32], y: &[f32]) -> f32 {
        use crate::kernels::tile;
        let dot = tile::dot(x, y);
        match self {
            Kernel::Softmax => dot.exp(),
            Kernel::Gaussian => (dot - tile::half_sq_norm(x) - tile::half_sq_norm(y)).exp(),
        }
    }
}

/// Empirical kernel matrix `phi(a_i, b_j)` through the fused score
/// kernels: the exp(dot [- norms]) epilogue is applied tile-by-tile, so
/// no `A B^T` intermediate is materialised beyond the output — the same
/// fusion the L1 Pallas kernel performs on-accelerator.
pub fn kernel_matrix(kernel: Kernel, a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.cols);
    let ctx = crate::kernels::KernelCtx::global();
    match kernel {
        Kernel::Softmax => crate::kernels::softmax_scores(ctx, a, b),
        Kernel::Gaussian => crate::kernels::gaussian_scores(ctx, a, b),
    }
}

/// How to invert the landmark Gram matrix.
#[derive(Debug, Clone, Copy)]
pub enum Inverse {
    /// Gauss–Jordan on `M + gamma I` (the CPU reference of §4.4).
    Exact { gamma: f32 },
    /// Preconditioned Newton–Schulz (the paper's accelerator-friendly path).
    NewtonSchulz { gamma: f32, iters: usize },
}

impl Inverse {
    fn apply(&self, m: &Matrix) -> Matrix {
        match *self {
            Inverse::Exact { gamma } => solve::gauss_jordan_inverse(&m.add_diag(gamma))
                .unwrap_or_else(|| solve::ns_inverse(m, gamma.max(1e-3), 30)),
            Inverse::NewtonSchulz { gamma, iters } => solve::ns_inverse(m, gamma, iters),
        }
    }
}

/// The modified Nyström approximation of `phi(q, k)` (n x m), using `d`
/// uniformly-sampled landmark rows of `[Q; K]`.
///
/// Never materialises the (n+m)^2 lifted matrix: only the three blocks
/// `phi(Q, L)`, `phi(L, L)`, `phi(L, K)` are formed — O((n+m) d) memory,
/// the paper's complexity claim.
pub fn modified_nystrom(
    kernel: Kernel,
    q: &Matrix,
    k: &Matrix,
    d: usize,
    inverse: Inverse,
    rng: &mut Rng,
) -> Matrix {
    let landmarks = rng.choose_distinct(q.rows + k.rows, d.min(q.rows + k.rows));
    modified_nystrom_with_landmarks(kernel, q, k, &landmarks, inverse)
}

/// Deterministic-landmark variant (tests, ablations).
pub fn modified_nystrom_with_landmarks(
    kernel: Kernel,
    q: &Matrix,
    k: &Matrix,
    landmarks: &[usize],
    inverse: Inverse,
) -> Matrix {
    let _span = obs::span("nystrom", "modified_nystrom");
    let x = q.vcat(k);
    let lm = x.take_rows(landmarks);
    let (c_ql, c_lk, gram) = {
        let _s = obs::span("nystrom", "kernel_blocks");
        (
            kernel_matrix(kernel, q, &lm),   // (n, d)
            kernel_matrix(kernel, &lm, k),   // (d, m)
            kernel_matrix(kernel, &lm, &lm), // (d, d) PSD
        )
    };
    let inv = {
        let _s = obs::span("nystrom", "inverse");
        inverse.apply(&gram)
    };
    let _s = obs::span("nystrom", "assemble");
    c_ql.matmul(&inv).matmul(&c_lk)
}

/// Apply the approximation directly to V without materialising (n, m):
/// `phi(Q,L) inv (phi(L,K) V)` — the O(n d) hot path.
pub fn modified_nystrom_apply(
    kernel: Kernel,
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    landmarks: &[usize],
    inverse: Inverse,
) -> Matrix {
    let _span = obs::span("nystrom", "modified_nystrom_apply");
    let x = q.vcat(k);
    let lm = x.take_rows(landmarks);
    let (c_ql, c_lk, gram) = {
        let _s = obs::span("nystrom", "kernel_blocks");
        (
            kernel_matrix(kernel, q, &lm),
            kernel_matrix(kernel, &lm, k),
            kernel_matrix(kernel, &lm, &lm),
        )
    };
    let inv = {
        let _s = obs::span("nystrom", "inverse");
        inverse.apply(&gram)
    };
    let _s = obs::span("nystrom", "assemble");
    c_ql.matmul(&inv.matmul(&c_lk.matmul(v)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::spectral_norm;

    fn qk(seed: u64, n: usize, p: usize, scale: f32) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let q = Matrix::randn(&mut rng, n, p, scale);
        let k = Matrix::randn(&mut rng, n, p, scale);
        (q, k)
    }

    #[test]
    fn kernel_matrix_gaussian_diag_is_one() {
        let (q, _) = qk(0, 20, 8, 0.7);
        let c = kernel_matrix(Kernel::Gaussian, &q, &q);
        for i in 0..20 {
            assert!((c[(i, i)] - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn scalar_eval_is_bit_identical_to_fused_kernel_matrix() {
        // eval shares the tile reductions and the exact epilogue
        // expression with the fused score kernels — entries must match
        // bit-for-bit, including at lane-boundary feature widths
        for &p in &[7usize, 8, 9, 17] {
            let (q, k) = qk(3, 12, p, 0.6);
            for kernel in [Kernel::Gaussian, Kernel::Softmax] {
                let c = kernel_matrix(kernel, &q, &k);
                for i in 0..q.rows {
                    for j in 0..k.rows {
                        assert_eq!(
                            c[(i, j)].to_bits(),
                            kernel.eval(q.row(i), k.row(j)).to_bits(),
                            "{kernel:?} p={p} ({i},{j})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn full_landmarks_recover_matrix() {
        let (q, k) = qk(1, 24, 8, 0.5);
        let c = kernel_matrix(Kernel::Gaussian, &q, &k);
        let landmarks: Vec<usize> = (0..48).collect();
        let approx = modified_nystrom_with_landmarks(
            Kernel::Gaussian,
            &q,
            &k,
            &landmarks,
            Inverse::Exact { gamma: 1e-6 },
        );
        let rel = spectral_norm(&c.sub(&approx)) / spectral_norm(&c);
        assert!(rel < 1e-2, "rel {rel}");
    }

    #[test]
    fn error_decreases_with_landmarks() {
        let (q, k) = qk(2, 96, 8, 0.4);
        let c = kernel_matrix(Kernel::Gaussian, &q, &k);
        let norm_c = spectral_norm(&c);
        let mut errs = Vec::new();
        for &d in &[8usize, 32, 128] {
            let mut avg = 0.0;
            for s in 0..3 {
                let mut rng = Rng::new(100 * d as u64 + s);
                let approx =
                    modified_nystrom(Kernel::Gaussian, &q, &k, d, Inverse::Exact { gamma: 1e-5 }, &mut rng);
                avg += spectral_norm(&c.sub(&approx)) / norm_c;
            }
            errs.push(avg / 3.0);
        }
        assert!(
            errs[2] < errs[0] * 0.6,
            "no decay across landmark counts: {errs:?}"
        );
    }

    #[test]
    fn ns_and_exact_inverse_agree_in_product() {
        let (q, k) = qk(3, 48, 8, 0.5);
        let landmarks: Vec<usize> = (0..32).collect();
        let a = modified_nystrom_with_landmarks(
            Kernel::Gaussian, &q, &k, &landmarks, Inverse::Exact { gamma: 1e-3 });
        let b = modified_nystrom_with_landmarks(
            Kernel::Gaussian, &q, &k, &landmarks, Inverse::NewtonSchulz { gamma: 1e-3, iters: 25 });
        let rel = spectral_norm(&a.sub(&b)) / spectral_norm(&a).max(1e-20);
        assert!(rel < 5e-3, "rel {rel}");
    }

    #[test]
    fn apply_matches_materialised() {
        let (q, k) = qk(4, 40, 8, 0.5);
        let mut rng = Rng::new(9);
        let v = Matrix::randn(&mut rng, 40, 16, 1.0);
        let landmarks: Vec<usize> = (0..24).collect();
        let inv = Inverse::NewtonSchulz { gamma: 1e-3, iters: 20 };
        let direct = modified_nystrom_apply(Kernel::Gaussian, &q, &k, &v, &landmarks, inv);
        let mat = modified_nystrom_with_landmarks(Kernel::Gaussian, &q, &k, &landmarks, inv)
            .matmul(&v);
        let err = direct.sub(&mat).max_abs();
        assert!(err < 1e-3, "err {err}");
    }

    #[test]
    fn softmax_kernel_lift_is_psd_spotcheck() {
        // Lemma 1: SM is a PSD kernel — check x^T C x >= 0 for random x
        let (q, k) = qk(5, 16, 6, 0.4);
        let x = q.vcat(&k);
        let c = kernel_matrix(Kernel::Softmax, &x, &x);
        let mut rng = Rng::new(11);
        for _ in 0..20 {
            let z: Vec<f32> = (0..32).map(|_| rng.normal()).collect();
            let cz = c.matvec(&z);
            let quad: f32 = z.iter().zip(&cz).map(|(a, b)| a * b).sum();
            assert!(quad > -1e-3, "negative quadratic form {quad}");
        }
    }
}
