//! Dynamic micro-batching: pure planning functions over the queue's
//! `VecDeque`, plus the blocking gather loop the dispatcher runs.
//!
//! The planning core ([`pop_leader`], [`take_compatible`]) takes the
//! deque and an explicit `now`, touching no clocks, locks, or threads —
//! so the batching policy is testable as plain data transformation
//! (tests/serve.rs drives it with synthetic timestamps).  Policy:
//!
//! * **Leader** = oldest live request (strict FIFO at the head;
//!   expired entries are shed, not served).
//! * **Compatibility** = same [`BucketKey`]: model kind + attention
//!   shape `(n, m, p, dv)`.  Head *count* is deliberately not part of
//!   the key — heads flatten into the one pool job either way.
//! * **FIFO within bucket**: the scan walks front-to-back and takes
//!   matching entries in queue order; non-matching entries keep their
//!   positions (no starvation reordering across buckets beyond the
//!   leader's bucket jumping the line).
//! * A batch closes at `max_batch` requests or when the leader has
//!   waited `max_wait` since the gather began, whichever comes first.

use std::collections::VecDeque;
use std::time::Instant;

use super::queue::{Pending, Queue};
use super::{ModelKind, Request, ServeConfig};

/// The coalescing key: requests batch together iff these agree (the
/// batched kernels require uniform item shapes within one job).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BucketKey {
    pub kind: ModelKind,
    /// Query length (rows of q).
    pub n: usize,
    /// Key/value length (rows of k and v).
    pub m: usize,
    /// Head width (cols of q and k).
    pub p: usize,
    /// Value width (cols of v).
    pub dv: usize,
}

impl BucketKey {
    /// The bucket of a validated request (first head is authoritative;
    /// admission validation guarantees the rest agree).
    pub fn of(req: &Request) -> BucketKey {
        let h = req.heads.first().expect("validated request has heads");
        BucketKey { kind: req.kind, n: h.q.rows, m: h.k.rows, p: h.q.cols, dv: h.v.cols }
    }
}

/// Pop the oldest live entry, shedding every expired entry in front of
/// it.  Pure: no clock, no lock — `now` is the caller's.
pub(crate) fn pop_leader(items: &mut VecDeque<Pending>, now: Instant) -> Option<Pending> {
    while let Some(p) = items.pop_front() {
        if p.req.expired(now) {
            p.shed_expired();
        } else {
            return Some(p);
        }
    }
    None
}

/// One gather pass: walk `items` front-to-back, shedding expired
/// entries and moving entries whose bucket matches `key` into `batch`
/// (in queue order), until `batch` holds `max_batch`.  Entries of other
/// buckets are left in place, in order.
pub(crate) fn take_compatible(
    items: &mut VecDeque<Pending>,
    batch: &mut Vec<Pending>,
    key: &BucketKey,
    max_batch: usize,
    now: Instant,
) {
    let mut i = 0;
    while i < items.len() && batch.len() < max_batch {
        if items[i].req.expired(now) {
            items.remove(i).expect("index in bounds").shed_expired();
        } else if BucketKey::of(&items[i].req) == *key {
            batch.push(items.remove(i).expect("index in bounds"));
        } else {
            i += 1;
        }
    }
}

/// The dispatcher's blocking gather: pop a leader (blocks while the
/// queue is open and empty), then coalesce its bucket until `max_batch`
/// or the `max_wait` timer.  `None` = queue closed and fully drained.
pub(crate) fn next_batch(queue: &Queue, cfg: &ServeConfig) -> Option<Vec<Pending>> {
    let leader = queue.pop_leader()?;
    let _span = crate::obs::span("serve", "gather");
    let key = BucketKey::of(&leader.req);
    let until = Instant::now() + cfg.max_wait;
    let mut batch = vec![leader];
    loop {
        // `seen` is the arrival generation this gather pass observed;
        // wait_for_arrival only wakes for pushes newer than it, so a
        // backlog of incompatible requests blocks here (until the
        // timer) instead of spinning the loop
        let seen = queue.take_compatible(&mut batch, &key, cfg.max_batch);
        if batch.len() >= cfg.max_batch || !queue.wait_for_arrival(until, seen) {
            return Some(batch);
        }
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    use super::super::{Head, Outcome, ShedReason, Ticket, TicketState};
    use super::*;
    use crate::linalg::Matrix;

    fn request(id: u64, kind: ModelKind, n: usize, deadline: Option<Instant>) -> Request {
        Request {
            id,
            kind,
            heads: vec![Head {
                q: Matrix::zeros(n, 3),
                k: Matrix::zeros(4, 3),
                v: Matrix::zeros(4, 2),
            }],
            deadline,
        }
    }

    fn pending(req: Request) -> (Pending, Ticket) {
        let state = Arc::new(TicketState::default());
        (Pending::new(req, Arc::clone(&state)), Ticket(state))
    }

    #[test]
    fn bucket_key_separates_kind_and_shape() {
        let a = request(0, ModelKind::Exact, 8, None);
        let b = request(1, ModelKind::Kernelized, 8, None);
        let c = request(2, ModelKind::Exact, 9, None);
        let d = request(3, ModelKind::Exact, 8, None);
        assert_ne!(BucketKey::of(&a), BucketKey::of(&b));
        assert_ne!(BucketKey::of(&a), BucketKey::of(&c));
        assert_eq!(BucketKey::of(&a), BucketKey::of(&d));
    }

    #[test]
    fn pop_leader_sheds_expired_prefix() {
        let now = Instant::now();
        let past = Some(now - Duration::from_millis(1));
        let mut items = VecDeque::new();
        let (p1, t1) = pending(request(1, ModelKind::Exact, 8, past));
        let (p2, _t2) = pending(request(2, ModelKind::Exact, 8, None));
        items.push_back(p1);
        items.push_back(p2);
        let leader = pop_leader(&mut items, now).unwrap();
        assert_eq!(leader.req.id, 2);
        assert!(matches!(t1.wait(), Outcome::Shed(ShedReason::DeadlineExpired)));
        assert!(items.is_empty());
    }

    #[test]
    fn take_compatible_is_fifo_within_bucket_and_leaves_others() {
        let now = Instant::now();
        let mut items = VecDeque::new();
        let mut tickets = Vec::new();
        // interleave two buckets: exact ids 1,3,5 / kernelized ids 2,4
        for id in 1..=5u64 {
            let kind = if id % 2 == 1 { ModelKind::Exact } else { ModelKind::Kernelized };
            let (p, t) = pending(request(id, kind, 8, None));
            items.push_back(p);
            tickets.push(t);
        }
        let key = BucketKey::of(&request(0, ModelKind::Exact, 8, None));
        let mut batch = Vec::new();
        take_compatible(&mut items, &mut batch, &key, 8, now);
        let got: Vec<u64> = batch.iter().map(|p| p.req.id).collect();
        assert_eq!(got, vec![1, 3, 5], "FIFO within the bucket");
        let left: Vec<u64> = items.iter().map(|p| p.req.id).collect();
        assert_eq!(left, vec![2, 4], "other buckets untouched, in order");
    }

    #[test]
    fn take_compatible_respects_max_batch() {
        let now = Instant::now();
        let mut items = VecDeque::new();
        let mut tickets = Vec::new();
        for id in 0..10u64 {
            let (p, t) = pending(request(id, ModelKind::Exact, 8, None));
            items.push_back(p);
            tickets.push(t);
        }
        let key = BucketKey::of(&request(0, ModelKind::Exact, 8, None));
        let mut batch = Vec::new();
        take_compatible(&mut items, &mut batch, &key, 4, now);
        assert_eq!(batch.len(), 4);
        assert_eq!(items.len(), 6);
        // the four taken are the four oldest
        assert_eq!(batch.iter().map(|p| p.req.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }

    /// Randomized sweep over queue contents: for any mix of buckets,
    /// expiry states, and `max_batch`, one gather pass must (a) never
    /// exceed `max_batch`, (b) take only live key-matching entries in
    /// FIFO order, (c) keep everything it leaves behind in order, and
    /// (d) drop an entry only by shedding it as expired.
    #[test]
    fn prop_gather_pass_invariants() {
        for case in 0..200u64 {
            let mut rng = crate::util::rng::Rng::new(case);
            let now = Instant::now();
            let past = Some(now - Duration::from_millis(1));
            let len = rng.below(24);
            let mut items = VecDeque::new();
            let mut tickets = Vec::new();
            let mut expired_ids = Vec::new();
            for id in 0..len as u64 {
                let kind = if rng.below(2) == 0 { ModelKind::Exact } else { ModelKind::Kernelized };
                let n = [6, 8, 9][rng.below(3)];
                let deadline = if rng.below(4) == 0 {
                    expired_ids.push(id);
                    past
                } else {
                    None
                };
                let (p, t) = pending(request(id, kind, n, deadline));
                items.push_back(p);
                tickets.push(t);
            }
            let key = BucketKey::of(&request(u64::MAX, ModelKind::Exact, 8, None));
            let max_batch = 1 + rng.below(6);
            let mut batch = Vec::new();
            take_compatible(&mut items, &mut batch, &key, max_batch, now);

            assert!(batch.len() <= max_batch, "case {case}: batch over max_batch");
            let batch_ids: Vec<u64> = batch.iter().map(|p| p.req.id).collect();
            let left_ids: Vec<u64> = items.iter().map(|p| p.req.id).collect();
            assert!(
                batch_ids.windows(2).all(|w| w[0] < w[1]),
                "case {case}: batch not FIFO: {batch_ids:?}"
            );
            assert!(
                left_ids.windows(2).all(|w| w[0] < w[1]),
                "case {case}: remainder reordered: {left_ids:?}"
            );
            for p in &batch {
                assert_eq!(BucketKey::of(&p.req), key, "case {case}: foreign bucket in batch");
                assert!(!p.req.expired(now), "case {case}: expired entry served");
            }
            // ids are assigned 0..len, so set arithmetic over Vec works
            for id in 0..len as u64 {
                let kept = batch_ids.contains(&id) || left_ids.contains(&id);
                if !kept {
                    assert!(
                        expired_ids.contains(&id),
                        "case {case}: live request {id} vanished without shedding"
                    );
                    assert!(
                        matches!(
                            tickets[id as usize].poll(),
                            Some(Outcome::Shed(ShedReason::DeadlineExpired))
                        ),
                        "case {case}: dropped entry {id} not resolved as deadline shed"
                    );
                }
            }
        }
    }

    #[test]
    fn take_compatible_sheds_expired_of_any_bucket() {
        let now = Instant::now();
        let past = Some(now - Duration::from_millis(1));
        let mut items = VecDeque::new();
        let (p1, t1) = pending(request(1, ModelKind::Kernelized, 8, past));
        let (p2, _t2) = pending(request(2, ModelKind::Exact, 8, None));
        items.push_back(p1);
        items.push_back(p2);
        let key = BucketKey::of(&request(0, ModelKind::Exact, 8, None));
        let mut batch = Vec::new();
        take_compatible(&mut items, &mut batch, &key, 8, now);
        assert!(matches!(t1.wait(), Outcome::Shed(ShedReason::DeadlineExpired)));
        assert_eq!(batch.len(), 1);
        assert!(items.is_empty());
    }
}
