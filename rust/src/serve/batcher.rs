//! Dynamic micro-batching: a pure planning core over queue snapshots,
//! thin application helpers over the queue's `VecDeque`, and the
//! blocking gather loop each shard gatherer runs.
//!
//! The planning core ([`plan_leader`], [`plan_gather`]) takes a slice
//! of [`Slot`]s (one per queued request, in queue order) and an
//! explicit `now`, touching no clocks, locks, or threads — so the
//! batching *and* priority policy is testable as plain data
//! transformation (`tests/proptests.rs` drives it with synthetic
//! timestamps).  Policy:
//!
//! * **Leader** = the oldest live request of the winning lane:
//!   [`Priority::High`] wins unless the oldest live
//!   [`Priority::Normal`] request has waited longer than the
//!   starvation bound (`max_wait × starvation_factor`) *and* is older
//!   than the oldest live High request — the starvation escape hatch.
//!   Expired entries are shed, not served.
//! * **Compatibility** = same [`BucketKey`]: model kind + attention
//!   shape `(n, m, p, dv)`.  Head *count* is deliberately not part of
//!   the key — heads flatten into the one pool job either way.
//! * **Per-lane FIFO within bucket**: the gather takes every matching
//!   high-lane entry in queue order, then matching normal-lane entries
//!   in queue order, until `max_batch`.  Non-matching entries keep
//!   their relative positions (no starvation reordering across buckets
//!   beyond the leader's bucket jumping the line).
//! * A batch closes at `max_batch` requests or when the leader has
//!   waited `max_wait` since the gather began, whichever comes first.
//!
//! Shard **routing** ([`BucketKey::shard`]) is a pure stable hash of
//! the bucket: every request of one bucket lands on the same shard, so
//! per-bucket per-lane FIFO survives sharding by construction.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::queue::{Pending, Queue};
use super::{ModelKind, Priority, Request, ServeConfig};

/// The coalescing key: requests batch together iff these agree (the
/// batched kernels require uniform item shapes within one job).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BucketKey {
    pub kind: ModelKind,
    /// Query length (rows of q).
    pub n: usize,
    /// Key/value length (rows of k and v).
    pub m: usize,
    /// Head width (cols of q and k).
    pub p: usize,
    /// Value width (cols of v).
    pub dv: usize,
}

impl BucketKey {
    /// The bucket of a validated request (first head is authoritative;
    /// admission validation guarantees the rest agree).
    pub fn of(req: &Request) -> BucketKey {
        let h = req.heads.first().expect("validated request has heads");
        BucketKey { kind: req.kind, n: h.q.rows, m: h.k.rows, p: h.q.cols, dv: h.v.cols }
    }

    /// Stable shard routing: FNV-1a over the bucket fields, mod
    /// `shards`.  A pure function of the key — the same bucket can
    /// never land on two shards, whatever the arrival order or timing
    /// (pinned by a proptest in `tests/proptests.rs`).
    pub fn shard(&self, shards: usize) -> usize {
        assert!(shards > 0, "shard() needs at least one shard");
        const FNV: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        let kind = match self.kind {
            ModelKind::Exact => 1u64,
            ModelKind::Kernelized => 2u64,
        };
        let mut h = FNV;
        for x in [kind, self.n as u64, self.m as u64, self.p as u64, self.dv as u64] {
            h = (h ^ x).wrapping_mul(FNV_PRIME);
        }
        (h % shards as u64) as usize
    }
}

/// One queued request as the pure planner sees it: bucket, lane, age,
/// and deadline — nothing else influences scheduling.
#[derive(Debug, Clone, Copy)]
pub struct Slot {
    pub bucket: BucketKey,
    pub priority: Priority,
    /// Admission timestamp (the starvation clock).
    pub enqueued: Instant,
    /// Absolute deadline; `None` never expires.
    pub deadline: Option<Instant>,
}

impl Slot {
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// What [`plan_leader`] decided: the index of the leader (into the
/// *original* slot slice) and the indices to shed as expired.
#[derive(Debug, Default, PartialEq, Eq)]
pub struct LeaderPlan {
    pub leader: Option<usize>,
    pub shed: Vec<usize>,
}

/// Pick the leader over a queue snapshot.  Pure: no clock, no lock —
/// `now` is the caller's.  Every expired slot is shed; among live
/// slots, the oldest High leads unless the oldest Normal has waited at
/// least `starve_after` *and* is older than that High.
pub fn plan_leader(slots: &[Slot], now: Instant, starve_after: Duration) -> LeaderPlan {
    let mut plan = LeaderPlan::default();
    let (mut high, mut normal) = (None::<usize>, None::<usize>);
    for (i, s) in slots.iter().enumerate() {
        if s.expired(now) {
            plan.shed.push(i);
        } else {
            match s.priority {
                Priority::High => high = high.or(Some(i)),
                Priority::Normal => normal = normal.or(Some(i)),
            }
        }
    }
    plan.leader = match (high, normal) {
        (Some(h), Some(n)) => {
            let n_slot = &slots[n];
            let starving = now.saturating_duration_since(n_slot.enqueued) >= starve_after;
            if starving && n_slot.enqueued < slots[h].enqueued {
                Some(n)
            } else {
                Some(h)
            }
        }
        (h, n) => h.or(n),
    };
    plan
}

/// What [`plan_gather`] decided: indices (into the original slot
/// slice) to move into the batch — high lane first, FIFO within each
/// lane — and the indices to shed as expired.
#[derive(Debug, Default, PartialEq, Eq)]
pub struct GatherPlan {
    pub take: Vec<usize>,
    pub shed: Vec<usize>,
}

/// Plan one gather pass over a queue snapshot: shed every expired
/// slot, then take slots whose bucket matches `key` — all high-lane
/// matches in queue order, then normal-lane matches in queue order —
/// until `room` slots are taken.  Pure; slots not taken or shed keep
/// their relative order.
pub fn plan_gather(slots: &[Slot], key: &BucketKey, room: usize, now: Instant) -> GatherPlan {
    let mut plan = GatherPlan::default();
    let mut normals = Vec::new();
    for (i, s) in slots.iter().enumerate() {
        if s.expired(now) {
            plan.shed.push(i);
        } else if s.bucket == *key {
            match s.priority {
                Priority::High => plan.take.push(i),
                Priority::Normal => normals.push(i),
            }
        }
    }
    plan.take.extend(normals);
    plan.take.truncate(room);
    plan
}

/// Snapshot the planner's view of a queue.
fn slots_of(items: &VecDeque<Pending>) -> Vec<Slot> {
    items
        .iter()
        .map(|p| Slot {
            bucket: BucketKey::of(&p.req),
            priority: p.req.priority,
            enqueued: p.enqueued,
            deadline: p.req.deadline,
        })
        .collect()
}

/// Remove the planned indices from `items`: `shed` entries resolve as
/// deadline-expired, `take` entries are returned *in plan order*.
/// Everything else keeps its relative queue position.
fn apply_plan(items: &mut VecDeque<Pending>, take: &[usize], shed: &[usize]) -> Vec<Pending> {
    let mut slots: Vec<Option<Pending>> = items.drain(..).map(Some).collect();
    for &i in shed {
        slots[i].take().expect("plan indices are disjoint").shed_expired();
    }
    let mut taken = Vec::with_capacity(take.len());
    for &i in take {
        taken.push(slots[i].take().expect("plan indices are disjoint"));
    }
    items.extend(slots.into_iter().flatten());
    taken
}

/// Pop the leader per [`plan_leader`], shedding every expired entry.
/// Pure application over the plan: no clock, no lock — `now` and
/// `starve_after` are the caller's.
pub(crate) fn pop_leader(
    items: &mut VecDeque<Pending>,
    now: Instant,
    starve_after: Duration,
) -> Option<Pending> {
    let plan = plan_leader(&slots_of(items), now, starve_after);
    let take: Vec<usize> = plan.leader.into_iter().collect();
    apply_plan(items, &take, &plan.shed).pop()
}

/// One gather pass per [`plan_gather`]: move matching entries into
/// `batch` (high lane first, FIFO per lane), shedding every expired
/// entry scanned, until `batch` holds `max_batch` requests.
pub(crate) fn take_compatible(
    items: &mut VecDeque<Pending>,
    batch: &mut Vec<Pending>,
    key: &BucketKey,
    max_batch: usize,
    now: Instant,
) {
    let room = max_batch.saturating_sub(batch.len());
    let plan = plan_gather(&slots_of(items), key, room, now);
    batch.extend(apply_plan(items, &plan.take, &plan.shed));
}

/// One shard gatherer's blocking gather: pop a leader (blocks while
/// the queue is open and empty), then coalesce its bucket until
/// `max_batch` or the `max_wait` timer.  `None` = queue closed and
/// fully drained.  `span_name` labels the gather span per shard
/// (`gather#<i>`).
pub(crate) fn next_batch(
    queue: &Queue,
    cfg: &ServeConfig,
    span_name: &str,
) -> Option<Vec<Pending>> {
    let leader = queue.pop_leader(cfg.starvation_bound())?;
    let _span = crate::obs::span("serve", span_name);
    let key = BucketKey::of(&leader.req);
    let until = Instant::now() + cfg.max_wait;
    let mut batch = vec![leader];
    loop {
        // `seen` is the arrival generation this gather pass observed;
        // wait_for_arrival only wakes for pushes newer than it, so a
        // backlog of incompatible requests blocks here (until the
        // timer) instead of spinning the loop
        let seen = queue.take_compatible(&mut batch, &key, cfg.max_batch);
        if batch.len() >= cfg.max_batch || !queue.wait_for_arrival(until, seen) {
            return Some(batch);
        }
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    use super::super::{Head, Outcome, ShedReason, Ticket, TicketState};
    use super::*;
    use crate::linalg::Matrix;

    fn request(id: u64, kind: ModelKind, n: usize, deadline: Option<Instant>) -> Request {
        Request {
            id,
            kind,
            heads: vec![Head {
                q: Matrix::zeros(n, 3),
                k: Matrix::zeros(4, 3),
                v: Matrix::zeros(4, 2),
            }],
            deadline,
            priority: Priority::Normal,
        }
    }

    fn pending(req: Request) -> (Pending, Ticket) {
        let state = Arc::new(TicketState::default());
        (Pending::new(req, Arc::clone(&state)), Ticket(state))
    }

    /// A pending with a synthetic admission timestamp (the starvation
    /// clock is the planner's input, not wall time).
    fn pending_at(req: Request, enqueued: Instant) -> (Pending, Ticket) {
        let (mut p, t) = pending(req);
        p.enqueued = enqueued;
        (p, t)
    }

    const NO_STARVE: Duration = Duration::from_secs(3600);

    #[test]
    fn bucket_key_separates_kind_and_shape() {
        let a = request(0, ModelKind::Exact, 8, None);
        let b = request(1, ModelKind::Kernelized, 8, None);
        let c = request(2, ModelKind::Exact, 9, None);
        let d = request(3, ModelKind::Exact, 8, None);
        assert_ne!(BucketKey::of(&a), BucketKey::of(&b));
        assert_ne!(BucketKey::of(&a), BucketKey::of(&c));
        assert_eq!(BucketKey::of(&a), BucketKey::of(&d));
    }

    #[test]
    fn shard_routing_is_stable_and_in_range() {
        for n in [6usize, 8, 9, 64] {
            for kind in [ModelKind::Exact, ModelKind::Kernelized] {
                let key = BucketKey::of(&request(0, kind, n, None));
                for shards in [1usize, 2, 3, 4, 7] {
                    let s = key.shard(shards);
                    assert!(s < shards);
                    assert_eq!(s, key.shard(shards), "routing must be pure");
                }
                assert_eq!(key.shard(1), 0);
            }
        }
    }

    #[test]
    fn pop_leader_sheds_expired_prefix() {
        let now = Instant::now();
        let past = Some(now - Duration::from_millis(1));
        let mut items = VecDeque::new();
        let (p1, t1) = pending(request(1, ModelKind::Exact, 8, past));
        let (p2, _t2) = pending(request(2, ModelKind::Exact, 8, None));
        items.push_back(p1);
        items.push_back(p2);
        let leader = pop_leader(&mut items, now, NO_STARVE).unwrap();
        assert_eq!(leader.req.id, 2);
        assert!(matches!(t1.wait(), Outcome::Shed(ShedReason::DeadlineExpired)));
        assert!(items.is_empty());
    }

    #[test]
    fn high_lane_leads_over_older_normal_within_bound() {
        let now = Instant::now();
        let mut items = VecDeque::new();
        // Normal admitted first (older), High second — High still leads
        let (p1, _t1) = pending_at(
            request(1, ModelKind::Exact, 8, None),
            now - Duration::from_millis(5),
        );
        let mut high = request(2, ModelKind::Exact, 8, None);
        high.priority = Priority::High;
        let (p2, _t2) = pending_at(high, now - Duration::from_millis(1));
        items.push_back(p1);
        items.push_back(p2);
        let leader = pop_leader(&mut items, now, Duration::from_millis(100)).unwrap();
        assert_eq!(leader.req.id, 2, "high lane leads inside the starvation bound");
        assert_eq!(items.len(), 1);
        assert_eq!(items[0].req.id, 1, "normal stays queued, position kept");
    }

    #[test]
    fn starved_normal_outranks_high() {
        let now = Instant::now();
        let mut items = VecDeque::new();
        let (p1, _t1) = pending_at(
            request(1, ModelKind::Exact, 8, None),
            now - Duration::from_millis(50),
        );
        let mut high = request(2, ModelKind::Exact, 8, None);
        high.priority = Priority::High;
        let (p2, _t2) = pending_at(high, now - Duration::from_millis(1));
        items.push_back(p1);
        items.push_back(p2);
        // bound = 10ms < the normal's 50ms wait, and the normal is older
        let leader = pop_leader(&mut items, now, Duration::from_millis(10)).unwrap();
        assert_eq!(leader.req.id, 1, "a starved older normal outranks high");
    }

    #[test]
    fn starved_normal_younger_than_high_does_not_outrank() {
        let now = Instant::now();
        let mut items = VecDeque::new();
        let mut high = request(1, ModelKind::Exact, 8, None);
        high.priority = Priority::High;
        let (p1, _t1) = pending_at(high, now - Duration::from_millis(80));
        let (p2, _t2) = pending_at(
            request(2, ModelKind::Exact, 8, None),
            now - Duration::from_millis(50),
        );
        items.push_back(p1);
        items.push_back(p2);
        let leader = pop_leader(&mut items, now, Duration::from_millis(10)).unwrap();
        assert_eq!(leader.req.id, 1, "an even older high still leads");
    }

    #[test]
    fn take_compatible_is_fifo_within_bucket_and_leaves_others() {
        let now = Instant::now();
        let mut items = VecDeque::new();
        let mut tickets = Vec::new();
        // interleave two buckets: exact ids 1,3,5 / kernelized ids 2,4
        for id in 1..=5u64 {
            let kind = if id % 2 == 1 { ModelKind::Exact } else { ModelKind::Kernelized };
            let (p, t) = pending(request(id, kind, 8, None));
            items.push_back(p);
            tickets.push(t);
        }
        let key = BucketKey::of(&request(0, ModelKind::Exact, 8, None));
        let mut batch = Vec::new();
        take_compatible(&mut items, &mut batch, &key, 8, now);
        let got: Vec<u64> = batch.iter().map(|p| p.req.id).collect();
        assert_eq!(got, vec![1, 3, 5], "FIFO within the bucket");
        let left: Vec<u64> = items.iter().map(|p| p.req.id).collect();
        assert_eq!(left, vec![2, 4], "other buckets untouched, in order");
    }

    #[test]
    fn take_compatible_gathers_high_lane_first_fifo_per_lane() {
        let now = Instant::now();
        let mut items = VecDeque::new();
        let mut tickets = Vec::new();
        // arrival order 1..=6, High on ids 2 and 5
        for id in 1..=6u64 {
            let mut req = request(id, ModelKind::Exact, 8, None);
            if id == 2 || id == 5 {
                req.priority = Priority::High;
            }
            let (p, t) = pending(req);
            items.push_back(p);
            tickets.push(t);
        }
        let key = BucketKey::of(&request(0, ModelKind::Exact, 8, None));
        let mut batch = Vec::new();
        take_compatible(&mut items, &mut batch, &key, 4, now);
        let got: Vec<u64> = batch.iter().map(|p| p.req.id).collect();
        assert_eq!(got, vec![2, 5, 1, 3], "high lane first, FIFO within each lane");
        let left: Vec<u64> = items.iter().map(|p| p.req.id).collect();
        assert_eq!(left, vec![4, 6], "remainder in order");
    }

    #[test]
    fn take_compatible_respects_max_batch() {
        let now = Instant::now();
        let mut items = VecDeque::new();
        let mut tickets = Vec::new();
        for id in 0..10u64 {
            let (p, t) = pending(request(id, ModelKind::Exact, 8, None));
            items.push_back(p);
            tickets.push(t);
        }
        let key = BucketKey::of(&request(0, ModelKind::Exact, 8, None));
        let mut batch = Vec::new();
        take_compatible(&mut items, &mut batch, &key, 4, now);
        assert_eq!(batch.len(), 4);
        assert_eq!(items.len(), 6);
        // the four taken are the four oldest
        assert_eq!(batch.iter().map(|p| p.req.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }

    /// Randomized sweep over queue contents: for any mix of buckets,
    /// lanes, expiry states, and `max_batch`, one gather pass must
    /// (a) never exceed `max_batch`, (b) take only live key-matching
    /// entries, high lane first and FIFO per lane, (c) keep everything
    /// it leaves behind in order, and (d) drop an entry only by
    /// shedding it as expired.
    #[test]
    fn prop_gather_pass_invariants() {
        for case in 0..200u64 {
            let mut rng = crate::util::rng::Rng::new(case);
            let now = Instant::now();
            let past = Some(now - Duration::from_millis(1));
            let len = rng.below(24);
            let mut items = VecDeque::new();
            let mut tickets = Vec::new();
            let mut expired_ids = Vec::new();
            let mut prio = Vec::new();
            for id in 0..len as u64 {
                let kind = if rng.below(2) == 0 { ModelKind::Exact } else { ModelKind::Kernelized };
                let n = [6, 8, 9][rng.below(3)];
                let deadline = if rng.below(4) == 0 {
                    expired_ids.push(id);
                    past
                } else {
                    None
                };
                let mut req = request(id, kind, n, deadline);
                if rng.below(3) == 0 {
                    req.priority = Priority::High;
                }
                prio.push(req.priority);
                let (p, t) = pending(req);
                items.push_back(p);
                tickets.push(t);
            }
            let key = BucketKey::of(&request(u64::MAX, ModelKind::Exact, 8, None));
            let max_batch = 1 + rng.below(6);
            let mut batch = Vec::new();
            take_compatible(&mut items, &mut batch, &key, max_batch, now);

            assert!(batch.len() <= max_batch, "case {case}: batch over max_batch");
            let batch_ids: Vec<u64> = batch.iter().map(|p| p.req.id).collect();
            let left_ids: Vec<u64> = items.iter().map(|p| p.req.id).collect();
            // the batch is the high-lane ids ascending, then normal ids
            // ascending — per-lane FIFO with high first
            let split = batch
                .iter()
                .position(|p| p.req.priority == Priority::Normal)
                .unwrap_or(batch.len());
            assert!(
                batch[..split].iter().all(|p| p.req.priority == Priority::High),
                "case {case}: normal before high: {batch_ids:?}"
            );
            assert!(
                batch[split..].iter().all(|p| p.req.priority == Priority::Normal),
                "case {case}: high after the normal tail: {batch_ids:?}"
            );
            assert!(
                batch_ids[..split].windows(2).all(|w| w[0] < w[1])
                    && batch_ids[split..].windows(2).all(|w| w[0] < w[1]),
                "case {case}: a lane is not FIFO: {batch_ids:?}"
            );
            assert!(
                left_ids.windows(2).all(|w| w[0] < w[1]),
                "case {case}: remainder reordered: {left_ids:?}"
            );
            for p in &batch {
                assert_eq!(BucketKey::of(&p.req), key, "case {case}: foreign bucket in batch");
                assert!(!p.req.expired(now), "case {case}: expired entry served");
            }
            // ids are assigned 0..len, so set arithmetic over Vec works
            for id in 0..len as u64 {
                let kept = batch_ids.contains(&id) || left_ids.contains(&id);
                if !kept {
                    assert!(
                        expired_ids.contains(&id),
                        "case {case}: live request {id} vanished without shedding"
                    );
                    assert!(
                        matches!(
                            tickets[id as usize].poll(),
                            Some(Outcome::Shed(ShedReason::DeadlineExpired))
                        ),
                        "case {case}: dropped entry {id} not resolved as deadline shed"
                    );
                }
            }
        }
    }

    #[test]
    fn take_compatible_sheds_expired_of_any_bucket() {
        let now = Instant::now();
        let past = Some(now - Duration::from_millis(1));
        let mut items = VecDeque::new();
        let (p1, t1) = pending(request(1, ModelKind::Kernelized, 8, past));
        let (p2, _t2) = pending(request(2, ModelKind::Exact, 8, None));
        items.push_back(p1);
        items.push_back(p2);
        let key = BucketKey::of(&request(0, ModelKind::Exact, 8, None));
        let mut batch = Vec::new();
        take_compatible(&mut items, &mut batch, &key, 8, now);
        assert!(matches!(t1.wait(), Outcome::Shed(ShedReason::DeadlineExpired)));
        assert_eq!(batch.len(), 1);
        assert!(items.is_empty());
    }
}
