//! The dispatch pipeline: N shard gatherers feeding one compute
//! submitter.
//!
//! Each shard runs [`run_shard`] — the gather loop over that shard's
//! own queue — and hands every formed batch across an MPSC channel to
//! the single [`run_submitter`] thread.  One batch = one call into the
//! batched kernels = one `run_rows` submission, regardless of how many
//! requests × heads the batch holds.  Funnelling every submission
//! through the one submitter thread keeps the serving layer from ever
//! tripping the pool's one-job-at-a-time submit lock from two sides,
//! no matter how many dispatcher shards are gathering.

use std::sync::mpsc;
use std::time::Instant;

use crate::kernels::{self, AttnItem, KernelCtx};
use crate::obs;

use super::queue::{Pending, Queue};
use super::{ModelKind, ServeConfig};

/// Shard gatherer main loop: gather batches from this shard's queue
/// until it is closed and drained, handing each batch to the compute
/// submitter.  Exits early if the submitter is gone (send fails) —
/// the queue teardown then resolves any still-queued tickets as
/// Dropped via the Pending safety-net.
pub(crate) fn run_shard(
    queue: &Queue,
    cfg: &ServeConfig,
    shard: usize,
    tx: &mpsc::Sender<Vec<Pending>>,
) {
    let span_name = format!("gather#{shard}");
    let batches_counter = format!("serve_shard_{shard}_batches_total");
    while let Some(batch) = super::batcher::next_batch(queue, cfg, &span_name) {
        obs::counter_add(&batches_counter, 1);
        if tx.send(batch).is_err() {
            return;
        }
    }
}

/// Compute-submitter main loop: the ONE thread that turns gathered
/// batches into pool jobs and resolves tickets.  Runs until every
/// shard gatherer has exited (all senders dropped).  Every `Pending`
/// that arrives here is resolved (completed or shed) before the next
/// batch is taken off the channel.
pub(crate) fn run_submitter(rx: &mpsc::Receiver<Vec<Pending>>, ctx: KernelCtx) {
    while let Ok(batch) = rx.recv() {
        run_batch(ctx, batch);
    }
}

/// Run one gathered batch: last-instant deadline check, one batched
/// kernel call for every surviving head, resolve every ticket.
pub(crate) fn run_batch(ctx: KernelCtx, batch: Vec<Pending>) {
    let _span = obs::span("serve", "dispatch");
    // gather→dispatch handoff is the last place shedding is cheap: a
    // request whose deadline passed while the batch was forming costs
    // nothing here, but would cost a full compute share one line later
    let now = Instant::now();
    let (expired, live): (Vec<Pending>, Vec<Pending>) =
        batch.into_iter().partition(|p| p.req.expired(now));
    for p in expired {
        p.shed_expired();
    }
    if live.is_empty() {
        return;
    }
    obs::observe("serve_batch_size", live.len() as f64);
    obs::counter_add("serve_batches_total", 1);

    let kind = live[0].req.kind;
    let items: Vec<AttnItem> = live
        .iter()
        .flat_map(|p| p.req.heads.iter().map(|h| AttnItem { q: &h.q, k: &h.k, v: &h.v }))
        .collect();
    let outputs = match kind {
        ModelKind::Exact => kernels::batched_softmax_attention(ctx, &items),
        ModelKind::Kernelized => kernels::batched_kernelized_attention(ctx, &items),
    };

    // hard asserts (release builds too): a count mismatch between the
    // batch's heads and the kernel's outputs would shift every
    // subsequent request onto the wrong matrices — fail loudly instead
    // of completing tickets with misassigned outputs.  A panic here
    // resolves the remaining tickets as Dropped via Pending's drop
    // safety-net, so clients don't hang.
    let mut outputs = outputs.into_iter();
    for p in live {
        let per_req: Vec<_> = outputs.by_ref().take(p.req.heads.len()).collect();
        assert_eq!(
            per_req.len(),
            p.req.heads.len(),
            "batched kernel returned fewer outputs than batch heads"
        );
        p.complete(per_req);
    }
    assert!(outputs.next().is_none(), "batched kernel returned more outputs than batch heads");
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    use super::super::{
        Head, ModelKind, Outcome, Priority, Request, ShedReason, Ticket, TicketState,
    };
    use super::*;
    use crate::linalg::Matrix;
    use crate::util::rng::Rng;

    fn request(id: u64, kind: ModelKind, heads: usize, deadline: Option<Instant>) -> Request {
        let mut rng = Rng::new(100 + id);
        let heads = (0..heads)
            .map(|_| Head {
                q: Matrix::randn(&mut rng, 6, 4, 0.5),
                k: Matrix::randn(&mut rng, 5, 4, 0.5),
                v: Matrix::randn(&mut rng, 5, 3, 1.0),
            })
            .collect();
        Request { id, kind, heads, deadline, priority: Priority::Normal }
    }

    fn pending(req: Request) -> (Pending, Ticket) {
        let state = Arc::new(TicketState::default());
        (Pending::new(req, Arc::clone(&state)), Ticket(state))
    }

    #[test]
    fn run_batch_completes_live_and_sheds_expired() {
        let ctx = KernelCtx::with_threads(2);
        let past = Some(Instant::now() - Duration::from_millis(1));
        let (p1, t1) = pending(request(1, ModelKind::Exact, 2, None));
        let (p2, t2) = pending(request(2, ModelKind::Exact, 1, past));
        let (p3, t3) = pending(request(3, ModelKind::Exact, 3, None));
        run_batch(ctx, vec![p1, p2, p3]);
        match t1.wait() {
            Outcome::Completed { outputs } => assert_eq!(outputs.len(), 2),
            other => panic!("expected completion, got {other:?}"),
        }
        assert!(matches!(t2.wait(), Outcome::Shed(ShedReason::DeadlineExpired)));
        match t3.wait() {
            Outcome::Completed { outputs } => {
                assert_eq!(outputs.len(), 3);
                assert_eq!((outputs[0].rows, outputs[0].cols), (6, 3));
            }
            other => panic!("expected completion, got {other:?}"),
        }
    }

    #[test]
    fn run_batch_output_matches_per_request_attention_bitwise() {
        let ctx = KernelCtx::with_threads(4);
        for kind in [ModelKind::Exact, ModelKind::Kernelized] {
            let req = request(9, kind, 2, None);
            let want: Vec<Matrix> = req
                .heads
                .iter()
                .map(|h| match kind {
                    ModelKind::Exact => {
                        crate::attention::exact::softmax_attention_in(ctx, &h.q, &h.k, &h.v)
                    }
                    ModelKind::Kernelized => {
                        crate::attention::exact::kernelized_attention_in(ctx, &h.q, &h.k, &h.v)
                    }
                })
                .collect();
            let (p, t) = pending(req);
            run_batch(ctx, vec![p]);
            let Outcome::Completed { outputs } = t.wait() else {
                panic!("expected completion")
            };
            for (got, want) in outputs.iter().zip(&want) {
                assert_eq!(got.rows, want.rows);
                for (x, y) in got.data.iter().zip(&want.data) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{kind:?}");
                }
            }
        }
    }

    /// The shard→submitter handoff end to end at module level: one
    /// shard queue, a real gatherer + submitter pair, tickets resolve.
    #[test]
    fn shard_and_submitter_pipeline_resolves_tickets() {
        let ctx = KernelCtx::with_threads(1);
        let cfg = ServeConfig { dispatchers: 1, ..ServeConfig::default() };
        let total = Arc::new(std::sync::atomic::AtomicIsize::new(0));
        let queue = Arc::new(Queue::for_shard(16, 93, total));
        let (tx, rx) = mpsc::channel::<Vec<Pending>>();

        let (p1, t1) = pending(request(1, ModelKind::Exact, 1, None));
        let (p2, t2) = pending(request(2, ModelKind::Kernelized, 1, None));
        queue.push(p1).unwrap();
        queue.push(p2).unwrap();
        queue.close();

        std::thread::scope(|s| {
            let q = Arc::clone(&queue);
            let gather = s.spawn(move || run_shard(&q, &cfg, 93, &tx));
            // tx moved into the gatherer and dropped when it exits, so
            // the submitter's recv() errs out once the queue drains
            let submit = s.spawn(move || run_submitter(&rx, ctx));
            gather.join().unwrap();
            submit.join().unwrap();
        });
        assert!(matches!(t1.wait(), Outcome::Completed { .. }));
        assert!(matches!(t2.wait(), Outcome::Completed { .. }));
    }
}
