//! Inference serving subsystem: bounded admission queue, dynamic
//! micro-batching, and deadline-aware batched dispatch (SERVING.md).
//!
//! The request path is three stages, each observable:
//!
//! 1. **Admission** ([`queue`]) — a bounded FIFO with backpressure.
//!    [`Server::submit`] never blocks: a full queue rejects with
//!    [`RejectReason::QueueFull`], a closed server with
//!    [`RejectReason::ShuttingDown`], a bad request with
//!    [`RejectReason::Malformed`].  Accepted requests return a
//!    [`Ticket`] the client blocks on.
//! 2. **Batching** ([`batcher`]) — the dispatcher pops the oldest live
//!    request (the *leader*) and coalesces compatible requests — same
//!    [`batcher::BucketKey`]: model kind + attention shape — behind it,
//!    FIFO within the bucket, until `max_batch` requests or the
//!    `max_wait` timer, whichever first.  Requests whose deadline passed
//!    are shed ([`ShedReason::DeadlineExpired`]) wherever they are met,
//!    before any compute is spent on them.
//! 3. **Dispatch** ([`dispatch`]) — every head of every request in the
//!    batch becomes one [`crate::kernels::AttnItem`] and the whole batch
//!    runs as **one** pool job via
//!    [`crate::kernels::batched_softmax_attention`] /
//!    [`crate::kernels::batched_kernelized_attention`].  Because each
//!    output row's arithmetic depends only on its own head, results are
//!    bit-identical to per-request dispatch no matter how the timer
//!    happened to slice batches — throughput from batching, bytes as if
//!    unbatched.
//!
//! [`Server::shutdown`] closes admission and *drains*: everything
//! already admitted still completes (or sheds on its deadline) before
//! the dispatcher exits.  Every accepted ticket resolves — completed,
//! shed, or (only if the server is torn down abnormally)
//! [`ShedReason::Dropped`]; `skyformer serve-bench` asserts the
//! zero-lost-requests invariant end to end.
//!
//! Metrics (OBSERVABILITY.md): `serve_queue_depth`, `serve_batch_size`,
//! `serve_request_latency_seconds`, `serve_rejects_total`,
//! `serve_deadline_sheds_total`, `serve_completed_total`,
//! `serve_batches_total`; spans under the `serve` category for the
//! gather and dispatch stages.

pub mod batcher;
pub mod dispatch;
pub mod queue;

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::kernels::KernelCtx;
use crate::linalg::Matrix;

/// Which attention path a request runs (the serving-facing subset of
/// the Figure-1 methods: the two exact quadratic paths the batched
/// kernels implement).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelKind {
    /// `softmax(q k^T) v` via the fused batched softmax kernel.
    Exact,
    /// Gaussian Kernelized Attention (paper Eq. 3), un-normalised.
    Kernelized,
}

impl ModelKind {
    pub fn parse(s: &str) -> Option<ModelKind> {
        match s {
            "exact" | "softmax" => Some(ModelKind::Exact),
            "kernelized" | "gaussian" => Some(ModelKind::Kernelized),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::Exact => "exact",
            ModelKind::Kernelized => "kernelized",
        }
    }
}

/// One attention head's inputs: `q (n x p)`, `k (m x p)`, `v (m x dv)`.
#[derive(Debug, Clone)]
pub struct Head {
    pub q: Matrix,
    pub k: Matrix,
    pub v: Matrix,
}

/// One inference request: all heads must share one attention shape
/// (checked at admission), but head *count* may differ between requests
/// in the same batch.
#[derive(Debug, Clone)]
pub struct Request {
    /// Client-chosen id, echoed in the outcome path for bookkeeping.
    pub id: u64,
    pub kind: ModelKind,
    pub heads: Vec<Head>,
    /// Absolute deadline; `None` means never shed.  A request past its
    /// deadline is shed wherever the pipeline next touches it — at
    /// leader pop, batch gather, or the final pre-compute check.
    pub deadline: Option<Instant>,
}

impl Request {
    /// True iff the deadline exists and has passed at `now`.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// Why an accepted request was resolved without outputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// The deadline passed before compute was spent on the request.
    DeadlineExpired,
    /// The server was torn down abnormally with the request still
    /// queued (never happens on a graceful [`Server::shutdown`] drain).
    Dropped,
}

/// Why a request was refused at admission (the request never entered
/// the queue; no ticket exists).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// The bounded queue is at capacity — backpressure; retry later.
    QueueFull,
    /// [`Server::shutdown`] has closed admission.
    ShuttingDown,
    /// The request fails shape validation (the message says how).
    Malformed(&'static str),
}

impl std::fmt::Display for RejectReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RejectReason::QueueFull => write!(f, "queue full"),
            RejectReason::ShuttingDown => write!(f, "shutting down"),
            RejectReason::Malformed(why) => write!(f, "malformed request: {why}"),
        }
    }
}

/// Terminal state of an accepted request.
#[derive(Debug, Clone)]
pub enum Outcome {
    /// One output matrix per head, in head order.
    Completed { outputs: Vec<Matrix> },
    Shed(ShedReason),
}

/// Set-once resolution slot a [`Ticket`] blocks on.
#[derive(Debug, Default)]
pub(crate) struct TicketState {
    slot: Mutex<Option<Outcome>>,
    done: Condvar,
}

impl TicketState {
    /// First resolution wins; later calls are no-ops (this is what lets
    /// [`queue::Pending`]'s drop safety-net coexist with explicit
    /// completion).
    pub(crate) fn resolve(&self, outcome: Outcome) {
        let mut slot = self.slot.lock().unwrap();
        if slot.is_none() {
            *slot = Some(outcome);
            self.done.notify_all();
        }
    }
}

/// The client's handle on an accepted request.
#[derive(Debug, Clone)]
pub struct Ticket(pub(crate) Arc<TicketState>);

impl Ticket {
    /// Block until the request resolves.  Every accepted request
    /// resolves: completion and deadline shedding in the normal course,
    /// [`ShedReason::Dropped`] as the teardown safety-net.
    pub fn wait(&self) -> Outcome {
        let mut slot = self.0.slot.lock().unwrap();
        loop {
            if let Some(outcome) = slot.clone() {
                return outcome;
            }
            slot = self.0.done.wait(slot).unwrap();
        }
    }

    /// Non-blocking probe.
    pub fn poll(&self) -> Option<Outcome> {
        self.0.slot.lock().unwrap().clone()
    }
}

/// Serving knobs (SERVING.md walks through the trade-offs).
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Admission bound: requests beyond this are rejected
    /// ([`RejectReason::QueueFull`]), never silently queued.
    pub queue_capacity: usize,
    /// Largest number of *requests* coalesced into one batch (heads
    /// within a request don't count against this; they always travel
    /// together).
    pub max_batch: usize,
    /// How long a batch leader waits for company before dispatching
    /// under-full.  Bounds the batching latency tax on a quiet server.
    pub max_wait: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            queue_capacity: 256,
            max_batch: 8,
            max_wait: Duration::from_micros(200),
        }
    }
}

/// A running serving instance: one admission queue + one dispatcher
/// thread.  The dispatcher is the only thread that submits pool jobs,
/// so each batch is exactly one `run_rows` submission and the pool's
/// one-job-at-a-time invariant holds by construction.
pub struct Server {
    queue: Arc<queue::Queue>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Spawn the dispatcher and open admission.
    pub fn start(cfg: ServeConfig, ctx: KernelCtx) -> Server {
        assert!(cfg.queue_capacity > 0, "queue_capacity must be > 0");
        assert!(cfg.max_batch > 0, "max_batch must be > 0");
        let queue = Arc::new(queue::Queue::new(cfg.queue_capacity));
        let q = Arc::clone(&queue);
        let dispatcher = std::thread::Builder::new()
            .name("serve-dispatch".into())
            .spawn(move || dispatch::run(&q, &cfg, ctx))
            .expect("spawn serve dispatcher");
        Server { queue, dispatcher: Some(dispatcher) }
    }

    /// Admit a request (non-blocking).  `Ok` hands back the ticket to
    /// wait on; `Err` means the request never entered the system.
    pub fn submit(&self, req: Request) -> Result<Ticket, RejectReason> {
        if let Err(why) = validate(&req) {
            crate::obs::counter_add("serve_rejects_total", 1);
            return Err(RejectReason::Malformed(why));
        }
        let state = Arc::new(TicketState::default());
        let pending = queue::Pending::new(req, Arc::clone(&state));
        self.queue.push(pending)?;
        Ok(Ticket(state))
    }

    /// Close admission and drain: blocks until every already-admitted
    /// request has resolved and the dispatcher has exited.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.queue.close();
        if let Some(handle) = self.dispatcher.take() {
            if handle.join().is_err() {
                // the dispatcher panicked; queued tickets resolve as
                // Dropped via Pending's drop safety-net when the queue
                // is torn down — nobody deadlocks on wait()
                eprintln!("serve: dispatcher thread panicked during drain");
            }
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Admission-time shape validation — the dispatcher may assert shapes,
/// the admission edge must not panic on client input.
fn validate(req: &Request) -> Result<(), &'static str> {
    let Some(first) = req.heads.first() else {
        return Err("request has no heads");
    };
    let dims = |h: &Head| (h.q.rows, h.k.rows, h.q.cols, h.v.cols);
    let want = dims(first);
    for h in &req.heads {
        if h.q.cols != h.k.cols {
            return Err("head q/k width mismatch");
        }
        if h.k.rows != h.v.rows {
            return Err("head k/v length mismatch");
        }
        if h.q.rows == 0 || h.k.rows == 0 || h.q.cols == 0 || h.v.cols == 0 {
            return Err("head has an empty dimension");
        }
        if dims(h) != want {
            return Err("heads of one request must share one shape");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn head(n: usize, m: usize, p: usize, dv: usize) -> Head {
        let mut rng = crate::util::rng::Rng::new(5);
        Head {
            q: Matrix::randn(&mut rng, n, p, 0.5),
            k: Matrix::randn(&mut rng, m, p, 0.5),
            v: Matrix::randn(&mut rng, m, dv, 1.0),
        }
    }

    #[test]
    fn model_kind_parse_roundtrip() {
        for kind in [ModelKind::Exact, ModelKind::Kernelized] {
            assert_eq!(ModelKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(ModelKind::parse("nystrom"), None);
    }

    #[test]
    fn validate_rejects_bad_shapes() {
        let ok = Request {
            id: 0,
            kind: ModelKind::Exact,
            heads: vec![head(4, 6, 3, 2), head(4, 6, 3, 2)],
            deadline: None,
        };
        assert!(validate(&ok).is_ok());
        assert!(validate(&Request { heads: vec![], ..ok.clone() }).is_err());
        assert!(validate(&Request {
            heads: vec![head(4, 6, 3, 2), head(5, 6, 3, 2)],
            ..ok.clone()
        })
        .is_err());
        let mut bad = head(4, 6, 3, 2);
        bad.k = Matrix::zeros(6, 9);
        assert!(validate(&Request { heads: vec![bad], ..ok }).is_err());
    }

    #[test]
    fn ticket_resolves_once() {
        let state = Arc::new(TicketState::default());
        let t = Ticket(Arc::clone(&state));
        state.resolve(Outcome::Shed(ShedReason::DeadlineExpired));
        state.resolve(Outcome::Shed(ShedReason::Dropped));
        match t.wait() {
            Outcome::Shed(ShedReason::DeadlineExpired) => {}
            other => panic!("first resolution should win, got {other:?}"),
        }
    }

    #[test]
    fn expired_logic() {
        let now = Instant::now();
        let req = Request {
            id: 1,
            kind: ModelKind::Exact,
            heads: vec![head(2, 2, 2, 2)],
            deadline: Some(now),
        };
        assert!(req.expired(now));
        assert!(!Request { deadline: None, ..req.clone() }.expired(now));
        assert!(!Request { deadline: Some(now + Duration::from_secs(1)), ..req }.expired(now));
    }
}
