//! Inference serving subsystem: sharded admission queues with priority
//! lanes, dynamic micro-batching, and deadline-aware batched dispatch
//! through a single compute submitter (SERVING.md).
//!
//! The request path is four stages, each observable:
//!
//! 1. **Admission + routing** ([`queue`]) — [`Server::submit`] validates
//!    the request, routes it by a stable hash of its
//!    [`batcher::BucketKey`] to one of `dispatchers` **shards** (each a
//!    bounded FIFO with backpressure), and never blocks: a full shard
//!    rejects with [`RejectReason::QueueFull`], a closed server with
//!    [`RejectReason::ShuttingDown`], a bad request with
//!    [`RejectReason::Malformed`].  Accepted requests return a
//!    [`Ticket`] the client blocks on.  Routing is a pure function of
//!    the bucket, so one bucket's backlog can never head-of-line-block
//!    another bucket that hashed to a different shard.
//! 2. **Batching** ([`batcher`]) — each shard's gatherer picks a leader
//!    by **priority lane** ([`Priority::High`] leads;
//!    [`Priority::Normal`] outranks it only past the starvation bound
//!    `max_wait × starvation_factor`) and coalesces compatible requests
//!    — same [`batcher::BucketKey`]: model kind + attention shape —
//!    behind it, high lane first, FIFO within each lane, until
//!    `max_batch` requests or the `max_wait` timer, whichever first.
//!    Requests whose deadline passed are shed
//!    ([`ShedReason::DeadlineExpired`]) wherever they are met, before
//!    any compute is spent on them.
//! 3. **Submission** ([`dispatch`]) — shards funnel gathered batches
//!    through one MPSC channel into the single **compute submitter**
//!    thread; it alone turns batches into pool jobs, so the kernel
//!    pool's one-job-at-a-time invariant holds by construction no
//!    matter how many shards gather concurrently.
//! 4. **Dispatch** ([`dispatch`]) — every head of every request in the
//!    batch becomes one [`crate::kernels::AttnItem`] and the whole batch
//!    runs as **one** pool job via
//!    [`crate::kernels::batched_softmax_attention`] /
//!    [`crate::kernels::batched_kernelized_attention`].  Because each
//!    output row's arithmetic depends only on its own head, results are
//!    bit-identical to per-request dispatch no matter how the timer,
//!    the shard count, or the priority lanes happened to slice batches
//!    — throughput from batching, bytes as if unbatched.
//!
//! [`Server::close`] closes admission without blocking;
//! [`Server::shutdown`] closes and *drains*: everything already admitted
//! still completes (or sheds on its deadline) before the shard
//! gatherers and the submitter exit.  Every accepted ticket resolves —
//! completed, shed, or (only if the server is torn down abnormally)
//! [`ShedReason::Dropped`]; `skyformer serve-bench` and
//! `rust/tests/serve_stress.rs` assert the zero-lost-requests invariant
//! end to end.
//!
//! Metrics (OBSERVABILITY.md): `serve_queue_depth`,
//! `serve_shard_<i>_queue_depth`, `serve_shard_<i>_batches_total`,
//! `serve_batch_size`, `serve_request_latency_seconds`,
//! `serve_rejects_total`, `serve_deadline_sheds_total`,
//! `serve_priority_sheds_total`, `serve_completed_total`,
//! `serve_batches_total`; spans under the `serve` category for the
//! per-shard gather (`gather#<i>`) and dispatch stages.

pub mod batcher;
pub mod dispatch;
pub mod queue;

use std::sync::atomic::AtomicIsize;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::kernels::KernelCtx;
use crate::linalg::Matrix;

/// Which attention path a request runs (the serving-facing subset of
/// the Figure-1 methods: the two exact quadratic paths the batched
/// kernels implement).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelKind {
    /// `softmax(q k^T) v` via the fused batched softmax kernel.
    Exact,
    /// Gaussian Kernelized Attention (paper Eq. 3), un-normalised.
    Kernelized,
}

impl ModelKind {
    pub fn parse(s: &str) -> Option<ModelKind> {
        match s {
            "exact" | "softmax" => Some(ModelKind::Exact),
            "kernelized" | "gaussian" => Some(ModelKind::Kernelized),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::Exact => "exact",
            ModelKind::Kernelized => "kernelized",
        }
    }
}

/// Admission-queue priority lane.  Priority changes *scheduling only* —
/// which request leads batch formation — never output bytes; the
/// determinism contract is lane-blind.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Priority {
    /// Leads batch formation ahead of [`Priority::Normal`] wherever a
    /// shard forms a batch.
    High,
    /// The default lane.  A Normal leader that has waited longer than
    /// the starvation bound (`max_wait × starvation_factor`) and is
    /// older than the oldest queued High request outranks the high
    /// lane, so Normal traffic is delayed but never starved.
    #[default]
    Normal,
}

impl Priority {
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "high" => Some(Priority::High),
            "normal" => Some(Priority::Normal),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
        }
    }
}

/// One attention head's inputs: `q (n x p)`, `k (m x p)`, `v (m x dv)`.
#[derive(Debug, Clone)]
pub struct Head {
    pub q: Matrix,
    pub k: Matrix,
    pub v: Matrix,
}

/// One inference request: all heads must share one attention shape
/// (checked at admission), but head *count* may differ between requests
/// in the same batch.
#[derive(Debug, Clone)]
pub struct Request {
    /// Client-chosen id, echoed in the outcome path for bookkeeping.
    pub id: u64,
    pub kind: ModelKind,
    pub heads: Vec<Head>,
    /// Absolute deadline; `None` means never shed.  A request past its
    /// deadline is shed wherever the pipeline next touches it — at
    /// leader selection, batch gather, or the final pre-compute check.
    pub deadline: Option<Instant>,
    /// Admission-queue lane (scheduling only; see [`Priority`]).
    pub priority: Priority,
}

impl Request {
    /// True iff the deadline exists and has passed at `now`.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// Why an accepted request was resolved without outputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// The deadline passed before compute was spent on the request.
    DeadlineExpired,
    /// The server was torn down abnormally with the request still
    /// queued (never happens on a graceful [`Server::shutdown`] drain).
    Dropped,
}

/// Why a request was refused at admission (the request never entered
/// the queue; no ticket exists).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// The request's shard queue is at capacity — backpressure; retry
    /// later.
    QueueFull,
    /// [`Server::close`] / [`Server::shutdown`] has closed admission.
    ShuttingDown,
    /// The request fails shape validation (the message says how).
    Malformed(&'static str),
}

impl std::fmt::Display for RejectReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RejectReason::QueueFull => write!(f, "queue full"),
            RejectReason::ShuttingDown => write!(f, "shutting down"),
            RejectReason::Malformed(why) => write!(f, "malformed request: {why}"),
        }
    }
}

/// Terminal state of an accepted request.
#[derive(Debug, Clone)]
pub enum Outcome {
    /// One output matrix per head, in head order.
    Completed { outputs: Vec<Matrix> },
    Shed(ShedReason),
}

/// Set-once resolution slot a [`Ticket`] blocks on.
#[derive(Debug, Default)]
pub(crate) struct TicketState {
    slot: Mutex<Option<Outcome>>,
    done: Condvar,
}

impl TicketState {
    /// First resolution wins; later calls are no-ops (this is what lets
    /// [`queue::Pending`]'s drop safety-net coexist with explicit
    /// completion).
    pub(crate) fn resolve(&self, outcome: Outcome) {
        let mut slot = self.slot.lock().unwrap();
        if slot.is_none() {
            *slot = Some(outcome);
            self.done.notify_all();
        }
    }
}

/// The client's handle on an accepted request.
#[derive(Debug, Clone)]
pub struct Ticket(pub(crate) Arc<TicketState>);

impl Ticket {
    /// Block until the request resolves.  Every accepted request
    /// resolves: completion and deadline shedding in the normal course,
    /// [`ShedReason::Dropped`] as the teardown safety-net.
    pub fn wait(&self) -> Outcome {
        let mut slot = self.0.slot.lock().unwrap();
        loop {
            if let Some(outcome) = slot.clone() {
                return outcome;
            }
            slot = self.0.done.wait(slot).unwrap();
        }
    }

    /// Non-blocking probe.
    pub fn poll(&self) -> Option<Outcome> {
        self.0.slot.lock().unwrap().clone()
    }
}

/// Serving knobs (SERVING.md walks through the trade-offs).
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Admission bound across the whole server: the bound is split
    /// evenly over the shards (`ceil(queue_capacity / dispatchers)`
    /// each); a full shard rejects with [`RejectReason::QueueFull`],
    /// never silently queues.
    pub queue_capacity: usize,
    /// Largest number of *requests* coalesced into one batch (heads
    /// within a request don't count against this; they always travel
    /// together).
    pub max_batch: usize,
    /// How long a batch leader waits for company before dispatching
    /// under-full.  Bounds the batching latency tax on a quiet server.
    pub max_wait: Duration,
    /// Dispatcher shards.  Each shard owns a disjoint set of buckets
    /// (stable hash of [`batcher::BucketKey`]) and gathers batches
    /// independently; all shards submit compute through one funnel.
    /// Default [`ServeConfig::default_dispatchers`] = `min(2, cores)`.
    pub dispatchers: usize,
    /// Starvation bound multiplier: a [`Priority::Normal`] leader older
    /// than `max_wait × starvation_factor` (and older than the oldest
    /// queued High request) outranks the high lane.
    pub starvation_factor: u32,
}

impl ServeConfig {
    /// The default shard count: `min(2, pool cores)` — sharding buys
    /// nothing on a single-core host.
    pub fn default_dispatchers() -> usize {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(2)
    }

    /// The age past which a Normal leader outranks the high lane.
    pub fn starvation_bound(&self) -> Duration {
        self.max_wait * self.starvation_factor
    }
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            queue_capacity: 256,
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            dispatchers: Self::default_dispatchers(),
            starvation_factor: 8,
        }
    }
}

/// A running serving instance: `dispatchers` shard queues, one gatherer
/// thread per shard, and **one** compute-submitter thread.  The
/// submitter is the only thread that submits pool jobs, so each batch
/// is exactly one `run_rows` submission and the pool's
/// one-job-at-a-time invariant holds however many shards gather
/// concurrently.
pub struct Server {
    shards: Vec<Arc<queue::Queue>>,
    gatherers: Vec<std::thread::JoinHandle<()>>,
    submitter: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Spawn the shard gatherers and the compute submitter, and open
    /// admission.
    pub fn start(cfg: ServeConfig, ctx: KernelCtx) -> Server {
        assert!(cfg.queue_capacity > 0, "queue_capacity must be > 0");
        assert!(cfg.max_batch > 0, "max_batch must be > 0");
        assert!(cfg.dispatchers > 0, "dispatchers must be > 0");
        let per_shard_cap = cfg.queue_capacity.div_ceil(cfg.dispatchers);
        let total_depth = Arc::new(AtomicIsize::new(0));
        let shards: Vec<Arc<queue::Queue>> = (0..cfg.dispatchers)
            .map(|s| Arc::new(queue::Queue::for_shard(per_shard_cap, s, Arc::clone(&total_depth))))
            .collect();
        // shards funnel gathered batches through this channel into the
        // single submitter — pool-job submission stays single-entry
        let (tx, rx) = mpsc::channel::<Vec<queue::Pending>>();
        let gatherers: Vec<_> = shards
            .iter()
            .enumerate()
            .map(|(s, q)| {
                let q = Arc::clone(q);
                let tx = tx.clone();
                std::thread::Builder::new()
                    .name(format!("serve-shard-{s}"))
                    .spawn(move || dispatch::run_shard(&q, &cfg, s, &tx))
                    .expect("spawn serve shard gatherer")
            })
            .collect();
        // the submitter exits when every gatherer has dropped its sender
        drop(tx);
        let submitter = std::thread::Builder::new()
            .name("serve-submit".into())
            .spawn(move || dispatch::run_submitter(&rx, ctx))
            .expect("spawn serve submitter");
        Server { shards, gatherers, submitter: Some(submitter) }
    }

    /// Admit a request (non-blocking).  `Ok` hands back the ticket to
    /// wait on; `Err` means the request never entered the system.
    /// Routing is a stable hash of the request's bucket, so every
    /// request of one bucket lands on the same shard (FIFO per lane is
    /// preserved per bucket).
    pub fn submit(&self, req: Request) -> Result<Ticket, RejectReason> {
        if let Err(why) = validate(&req) {
            crate::obs::counter_add("serve_rejects_total", 1);
            return Err(RejectReason::Malformed(why));
        }
        let shard = batcher::BucketKey::of(&req).shard(self.shards.len());
        let state = Arc::new(TicketState::default());
        let pending = queue::Pending::new(req, Arc::clone(&state));
        self.shards[shard].push(pending)?;
        Ok(Ticket(state))
    }

    /// Close admission without blocking: new submits get
    /// [`RejectReason::ShuttingDown`]; everything already admitted
    /// still drains.  Idempotent, callable from any thread — the
    /// stress suite races it against live submitters.  Follow with
    /// [`Server::shutdown`] (or drop) to block until the drain ends.
    pub fn close(&self) {
        for q in &self.shards {
            q.close();
        }
    }

    /// Close admission and drain: blocks until every already-admitted
    /// request has resolved and the shard gatherers + submitter have
    /// exited.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.close();
        let mut panicked = false;
        for handle in self.gatherers.drain(..) {
            panicked |= handle.join().is_err();
        }
        if let Some(handle) = self.submitter.take() {
            panicked |= handle.join().is_err();
        }
        if panicked {
            // a panicking gatherer/submitter drops its in-flight
            // Pendings, which resolve as Dropped via the drop
            // safety-net; leftovers still queued resolve when the shard
            // queues drop with the Server — nobody deadlocks on wait()
            eprintln!("serve: a serving thread panicked during drain");
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Admission-time shape validation — the dispatcher may assert shapes,
/// the admission edge must not panic on client input.
fn validate(req: &Request) -> Result<(), &'static str> {
    let Some(first) = req.heads.first() else {
        return Err("request has no heads");
    };
    let dims = |h: &Head| (h.q.rows, h.k.rows, h.q.cols, h.v.cols);
    let want = dims(first);
    for h in &req.heads {
        if h.q.cols != h.k.cols {
            return Err("head q/k width mismatch");
        }
        if h.k.rows != h.v.rows {
            return Err("head k/v length mismatch");
        }
        if h.q.rows == 0 || h.k.rows == 0 || h.q.cols == 0 || h.v.cols == 0 {
            return Err("head has an empty dimension");
        }
        if dims(h) != want {
            return Err("heads of one request must share one shape");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn head(n: usize, m: usize, p: usize, dv: usize) -> Head {
        let mut rng = crate::util::rng::Rng::new(5);
        Head {
            q: Matrix::randn(&mut rng, n, p, 0.5),
            k: Matrix::randn(&mut rng, m, p, 0.5),
            v: Matrix::randn(&mut rng, m, dv, 1.0),
        }
    }

    #[test]
    fn model_kind_parse_roundtrip() {
        for kind in [ModelKind::Exact, ModelKind::Kernelized] {
            assert_eq!(ModelKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(ModelKind::parse("nystrom"), None);
    }

    #[test]
    fn priority_parse_roundtrip_and_default() {
        for p in [Priority::High, Priority::Normal] {
            assert_eq!(Priority::parse(p.name()), Some(p));
        }
        assert_eq!(Priority::parse("urgent"), None);
        assert_eq!(Priority::default(), Priority::Normal);
    }

    #[test]
    fn default_dispatchers_is_at_most_two_and_positive() {
        let d = ServeConfig::default_dispatchers();
        assert!((1..=2).contains(&d), "min(2, cores) out of range: {d}");
        assert_eq!(ServeConfig::default().dispatchers, d);
    }

    #[test]
    fn starvation_bound_scales_max_wait() {
        let cfg = ServeConfig {
            max_wait: Duration::from_millis(3),
            starvation_factor: 5,
            ..ServeConfig::default()
        };
        assert_eq!(cfg.starvation_bound(), Duration::from_millis(15));
    }

    #[test]
    fn validate_rejects_bad_shapes() {
        let ok = Request {
            id: 0,
            kind: ModelKind::Exact,
            heads: vec![head(4, 6, 3, 2), head(4, 6, 3, 2)],
            deadline: None,
            priority: Priority::Normal,
        };
        assert!(validate(&ok).is_ok());
        assert!(validate(&Request { heads: vec![], ..ok.clone() }).is_err());
        assert!(validate(&Request {
            heads: vec![head(4, 6, 3, 2), head(5, 6, 3, 2)],
            ..ok.clone()
        })
        .is_err());
        let mut bad = head(4, 6, 3, 2);
        bad.k = Matrix::zeros(6, 9);
        assert!(validate(&Request { heads: vec![bad], ..ok }).is_err());
    }

    #[test]
    fn ticket_resolves_once() {
        let state = Arc::new(TicketState::default());
        let t = Ticket(Arc::clone(&state));
        state.resolve(Outcome::Shed(ShedReason::DeadlineExpired));
        state.resolve(Outcome::Shed(ShedReason::Dropped));
        match t.wait() {
            Outcome::Shed(ShedReason::DeadlineExpired) => {}
            other => panic!("first resolution should win, got {other:?}"),
        }
    }

    #[test]
    fn expired_logic() {
        let now = Instant::now();
        let req = Request {
            id: 1,
            kind: ModelKind::Exact,
            heads: vec![head(2, 2, 2, 2)],
            deadline: Some(now),
            priority: Priority::Normal,
        };
        assert!(req.expired(now));
        assert!(!Request { deadline: None, ..req.clone() }.expired(now));
        assert!(!Request { deadline: Some(now + Duration::from_secs(1)), ..req }.expired(now));
    }
}
