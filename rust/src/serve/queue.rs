//! Bounded admission queue with backpressure — the only mutable state
//! the serving subsystem shares between client threads and the
//! dispatcher.
//!
//! Invariants:
//!
//! * Capacity is a hard bound: [`Queue::push`] rejects (QueueFull /
//!   ShuttingDown) instead of blocking or growing — admission latency
//!   is O(lock), never O(traffic).
//! * Every [`Pending`] that enters the queue resolves its ticket
//!   exactly once.  The normal paths (complete / shed) resolve
//!   explicitly; a drop safety-net resolves anything else as
//!   [`ShedReason::Dropped`], so a client blocked on
//!   [`super::Ticket::wait`] can never deadlock on a torn-down server.
//! * `serve_queue_depth` tracks the live length on every transition.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::obs;

use super::{Outcome, RejectReason, Request, ShedReason, TicketState};

/// An admitted request travelling through the pipeline: the request,
/// its ticket, and its admission timestamp (the latency clock).
#[derive(Debug)]
pub(crate) struct Pending {
    pub req: Request,
    pub enqueued: Instant,
    ticket: Arc<TicketState>,
}

impl Pending {
    pub(crate) fn new(req: Request, ticket: Arc<TicketState>) -> Pending {
        Pending { req, enqueued: Instant::now(), ticket }
    }

    /// Resolve with outputs and record the request's end-to-end latency.
    pub(crate) fn complete(self, outputs: Vec<crate::linalg::Matrix>) {
        obs::observe("serve_request_latency_seconds", self.enqueued.elapsed().as_secs_f64());
        obs::counter_add("serve_completed_total", 1);
        self.ticket.resolve(Outcome::Completed { outputs });
    }

    /// Resolve as shed (deadline passed before compute).
    pub(crate) fn shed_expired(self) {
        obs::counter_add("serve_deadline_sheds_total", 1);
        self.ticket.resolve(Outcome::Shed(ShedReason::DeadlineExpired));
    }
}

impl Drop for Pending {
    fn drop(&mut self) {
        // safety-net: resolve() is set-once, so this is a no-op after
        // complete()/shed_expired() and only bites when a Pending is
        // discarded un-resolved (abnormal teardown, dispatcher panic)
        self.ticket.resolve(Outcome::Shed(ShedReason::Dropped));
    }
}

struct Inner {
    items: VecDeque<Pending>,
    closed: bool,
    /// Bumped on every successful push.  The batcher compares this
    /// against the generation its last gather pass observed, so a
    /// backlog it has already scanned (e.g. only foreign-bucket
    /// requests) can never read as "new arrivals".
    arrivals: u64,
}

/// Bounded MPSC queue: many client threads push, the one dispatcher
/// thread pops/scans under the same lock via the [`super::batcher`]
/// planning functions.
pub struct Queue {
    inner: Mutex<Inner>,
    arrived: Condvar,
    capacity: usize,
}

impl Queue {
    pub(crate) fn new(capacity: usize) -> Queue {
        Queue {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false, arrivals: 0 }),
            arrived: Condvar::new(),
            capacity,
        }
    }

    /// Admit or reject, never block.  On rejection the pending's ticket
    /// was never handed to a client (submit returns the error instead),
    /// so its drop-resolution is unobservable.
    pub(crate) fn push(&self, p: Pending) -> Result<(), RejectReason> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            obs::counter_add("serve_rejects_total", 1);
            return Err(RejectReason::ShuttingDown);
        }
        if inner.items.len() >= self.capacity {
            obs::counter_add("serve_rejects_total", 1);
            return Err(RejectReason::QueueFull);
        }
        inner.items.push_back(p);
        inner.arrivals += 1;
        obs::gauge_set("serve_queue_depth", inner.items.len() as f64);
        self.arrived.notify_one();
        Ok(())
    }

    /// Block until a live (non-expired) leader is available and pop it;
    /// `None` once the queue is closed *and* drained — the dispatcher's
    /// exit condition.  Expired requests are shed on the way.
    pub(crate) fn pop_leader(&self) -> Option<Pending> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            let leader = super::batcher::pop_leader(&mut inner.items, Instant::now());
            obs::gauge_set("serve_queue_depth", inner.items.len() as f64);
            if let Some(p) = leader {
                return Some(p);
            }
            if inner.closed {
                return None;
            }
            inner = self.arrived.wait(inner).unwrap();
        }
    }

    /// One gather pass: move queued requests compatible with `key` into
    /// `batch` (FIFO within the bucket), shedding any expired entry
    /// scanned, until `batch` holds `max_batch` requests.  Returns the
    /// arrival generation the pass observed — the `seen` token for
    /// [`Queue::wait_for_arrival`].
    pub(crate) fn take_compatible(
        &self,
        batch: &mut Vec<Pending>,
        key: &super::batcher::BucketKey,
        max_batch: usize,
    ) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        super::batcher::take_compatible(&mut inner.items, batch, key, max_batch, Instant::now());
        obs::gauge_set("serve_queue_depth", inner.items.len() as f64);
        inner.arrivals
    }

    /// Park until a push lands that the gather pass which observed
    /// `seen` has not scanned, or `until` passes.  The timer is
    /// authoritative: once `until` is reached this returns false even
    /// if the queue is non-empty — a backlog of foreign-bucket requests
    /// the batcher has already walked must not keep a partial batch
    /// from dispatching (those requests get their turn as the next
    /// leader).  Also returns false when the queue is closed with no
    /// unseen arrivals — the batcher then dispatches what it has.
    pub(crate) fn wait_for_arrival(&self, until: Instant, seen: u64) -> bool {
        let mut inner = self.inner.lock().unwrap();
        loop {
            let now = Instant::now();
            let Some(left) = until.checked_duration_since(now).filter(|d| !d.is_zero()) else {
                return false;
            };
            if inner.arrivals != seen {
                return true;
            }
            if inner.closed {
                return false;
            }
            let (guard, _timeout) = self.arrived.wait_timeout(inner, left).unwrap();
            inner = guard;
        }
    }

    /// Close admission (push rejects from now on) and wake the
    /// dispatcher so it drains and exits.
    pub(crate) fn close(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.closed = true;
        self.arrived.notify_all();
    }

    #[cfg(test)]
    pub(crate) fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Head, ModelKind, ShedReason, Ticket};
    use super::*;
    use crate::linalg::Matrix;

    fn request(id: u64) -> Request {
        Request {
            id,
            kind: ModelKind::Exact,
            heads: vec![Head {
                q: Matrix::zeros(2, 2),
                k: Matrix::zeros(2, 2),
                v: Matrix::zeros(2, 2),
            }],
            deadline: None,
        }
    }

    fn pending(id: u64) -> (Pending, Ticket) {
        let state = Arc::new(TicketState::default());
        (Pending::new(request(id), Arc::clone(&state)), Ticket(state))
    }

    #[test]
    fn bounded_push_rejects_when_full() {
        let q = Queue::new(2);
        let (p1, _t1) = pending(1);
        let (p2, _t2) = pending(2);
        let (p3, _t3) = pending(3);
        assert!(q.push(p1).is_ok());
        assert!(q.push(p2).is_ok());
        assert!(matches!(q.push(p3), Err(RejectReason::QueueFull)));
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn closed_push_rejects_shutting_down() {
        let q = Queue::new(4);
        q.close();
        let (p, _t) = pending(1);
        assert!(matches!(q.push(p), Err(RejectReason::ShuttingDown)));
    }

    #[test]
    fn pop_leader_drains_then_returns_none_when_closed() {
        let q = Queue::new(4);
        let (p, _t) = pending(7);
        q.push(p).unwrap();
        q.close();
        assert_eq!(q.pop_leader().unwrap().req.id, 7);
        assert!(q.pop_leader().is_none());
    }

    #[test]
    fn dropped_pending_resolves_ticket() {
        let (p, t) = pending(1);
        drop(p);
        match t.wait() {
            Outcome::Shed(ShedReason::Dropped) => {}
            other => panic!("expected Dropped, got {other:?}"),
        }
    }

    #[test]
    fn wait_for_arrival_times_out_on_empty_queue() {
        let q = Queue::new(4);
        let until = Instant::now() + std::time::Duration::from_millis(5);
        assert!(!q.wait_for_arrival(until, 0));
    }

    /// Regression for the gather-loop livelock: a backlog the batcher
    /// has already scanned (here a foreign-bucket request) must not
    /// defeat the timer — `wait_for_arrival` has to block and then
    /// report false at the deadline, not return true instantly because
    /// the queue is non-empty.
    #[test]
    fn wait_for_arrival_times_out_with_only_scanned_backlog() {
        let q = Queue::new(4);
        let (p, _t) = pending(1);
        q.push(p).unwrap();
        // a gather pass for a bucket nothing matches: takes nothing,
        // observes the current arrival generation
        let foreign = super::super::batcher::BucketKey {
            kind: ModelKind::Kernelized,
            n: 2,
            m: 2,
            p: 2,
            dv: 2,
        };
        let mut batch = Vec::new();
        let seen = q.take_compatible(&mut batch, &foreign, 4);
        assert!(batch.is_empty());
        let start = Instant::now();
        let until = start + std::time::Duration::from_millis(5);
        assert!(!q.wait_for_arrival(until, seen), "stale backlog must not read as arrival");
        assert!(start.elapsed() >= std::time::Duration::from_millis(5), "must block, not spin");
        assert_eq!(q.len(), 1, "foreign request still queued for the next leader pop");
    }

    #[test]
    fn wait_for_arrival_sees_push_after_gather() {
        let q = Queue::new(4);
        let foreign = super::super::batcher::BucketKey {
            kind: ModelKind::Kernelized,
            n: 2,
            m: 2,
            p: 2,
            dv: 2,
        };
        let seen = q.take_compatible(&mut Vec::new(), &foreign, 4);
        let (p, _t) = pending(1);
        q.push(p).unwrap();
        let until = Instant::now() + std::time::Duration::from_secs(5);
        assert!(q.wait_for_arrival(until, seen), "push after the gather pass is a new arrival");
    }
}
