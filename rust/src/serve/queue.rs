//! Bounded admission queue with backpressure — one instance per
//! dispatcher shard, the only mutable state the serving subsystem
//! shares between client threads and that shard's gatherer.
//!
//! Invariants:
//!
//! * Capacity is a hard bound: [`Queue::push`] rejects (QueueFull /
//!   ShuttingDown) instead of blocking or growing — admission latency
//!   is O(lock), never O(traffic).
//! * Every [`Pending`] that enters the queue resolves its ticket
//!   exactly once.  The normal paths (complete / shed) resolve
//!   explicitly; a drop safety-net resolves anything else as
//!   [`ShedReason::Dropped`], so a client blocked on
//!   [`super::Ticket::wait`] can never deadlock on a torn-down server.
//! * Depth gauges never go stale: `serve_shard_<i>_queue_depth` (this
//!   shard) and `serve_queue_depth` (the sum over shards, via a shared
//!   counter) are republished on every push/take/shed, on
//!   [`Queue::close`], and when the queue itself is torn down with
//!   entries still inside — a drained shut-down server always reads
//!   depth 0.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicIsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::obs;

use super::{Outcome, Priority, RejectReason, Request, ShedReason, TicketState};

/// An admitted request travelling through the pipeline: the request,
/// its ticket, and its admission timestamp (the latency clock and the
/// starvation clock).
#[derive(Debug)]
pub(crate) struct Pending {
    pub req: Request,
    pub enqueued: Instant,
    ticket: Arc<TicketState>,
}

impl Pending {
    pub(crate) fn new(req: Request, ticket: Arc<TicketState>) -> Pending {
        Pending { req, enqueued: Instant::now(), ticket }
    }

    /// Resolve with outputs and record the request's end-to-end latency.
    pub(crate) fn complete(self, outputs: Vec<crate::linalg::Matrix>) {
        obs::observe("serve_request_latency_seconds", self.enqueued.elapsed().as_secs_f64());
        obs::counter_add("serve_completed_total", 1);
        self.ticket.resolve(Outcome::Completed { outputs });
    }

    /// Resolve as shed (deadline passed before compute).  Sheds that
    /// hit the high lane are counted separately — with priority lanes
    /// doing their job, `serve_priority_sheds_total` should stay near
    /// zero while Normal absorbs the deadline pressure.
    pub(crate) fn shed_expired(self) {
        obs::counter_add("serve_deadline_sheds_total", 1);
        if self.req.priority == Priority::High {
            obs::counter_add("serve_priority_sheds_total", 1);
        }
        self.ticket.resolve(Outcome::Shed(ShedReason::DeadlineExpired));
    }
}

impl Drop for Pending {
    fn drop(&mut self) {
        // safety-net: resolve() is set-once, so this is a no-op after
        // complete()/shed_expired() and only bites when a Pending is
        // discarded un-resolved (abnormal teardown, dispatcher panic)
        self.ticket.resolve(Outcome::Shed(ShedReason::Dropped));
    }
}

struct Inner {
    items: VecDeque<Pending>,
    closed: bool,
    /// Bumped on every successful push.  The batcher compares this
    /// against the generation its last gather pass observed, so a
    /// backlog it has already scanned (e.g. only foreign-bucket
    /// requests) can never read as "new arrivals".
    arrivals: u64,
    /// The length last folded into the shared total-depth counter —
    /// the delta source for `serve_queue_depth`.
    published: usize,
}

/// Bounded MPSC queue: many client threads push, this shard's one
/// gatherer thread pops/scans under the same lock via the
/// [`super::batcher`] planning functions.
pub struct Queue {
    inner: Mutex<Inner>,
    arrived: Condvar,
    capacity: usize,
    /// Gauge name `serve_shard_<i>_queue_depth`, precomputed.
    depth_gauge: String,
    /// Live depth summed across every shard of the same server —
    /// backs the aggregate `serve_queue_depth` gauge.
    total: Arc<AtomicIsize>,
}

impl Queue {
    /// A shard's queue: `shard` names the per-shard depth gauge,
    /// `total` is the server-wide depth counter shared by every shard.
    pub(crate) fn for_shard(capacity: usize, shard: usize, total: Arc<AtomicIsize>) -> Queue {
        Queue {
            inner: Mutex::new(Inner {
                items: VecDeque::new(),
                closed: false,
                arrivals: 0,
                published: 0,
            }),
            arrived: Condvar::new(),
            capacity,
            depth_gauge: format!("serve_shard_{shard}_queue_depth"),
            total,
        }
    }

    #[cfg(test)]
    pub(crate) fn new(capacity: usize) -> Queue {
        Queue::for_shard(capacity, 0, Arc::new(AtomicIsize::new(0)))
    }

    /// Republish both depth gauges from the current queue length.
    /// Called on every state transition *and* on close/teardown, so a
    /// drained or torn-down queue can never leave a stale nonzero
    /// depth behind.
    fn publish_depth(&self, inner: &mut Inner) {
        let len = inner.items.len();
        let delta = len as isize - inner.published as isize;
        let total = if delta != 0 {
            self.total.fetch_add(delta, Ordering::Relaxed) + delta
        } else {
            self.total.load(Ordering::Relaxed)
        };
        inner.published = len;
        obs::gauge_set("serve_queue_depth", total.max(0) as f64);
        obs::gauge_set(&self.depth_gauge, len as f64);
    }

    /// Admit or reject, never block.  On rejection the pending's ticket
    /// was never handed to a client (submit returns the error instead),
    /// so its drop-resolution is unobservable.
    pub(crate) fn push(&self, p: Pending) -> Result<(), RejectReason> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            obs::counter_add("serve_rejects_total", 1);
            return Err(RejectReason::ShuttingDown);
        }
        if inner.items.len() >= self.capacity {
            obs::counter_add("serve_rejects_total", 1);
            return Err(RejectReason::QueueFull);
        }
        inner.items.push_back(p);
        inner.arrivals += 1;
        self.publish_depth(&mut inner);
        self.arrived.notify_one();
        Ok(())
    }

    /// Block until a live (non-expired) leader is available and pop it;
    /// `None` once the queue is closed *and* drained — the gatherer's
    /// exit condition.  Leader choice is lane-aware (`High` leads,
    /// `starve_after` is the Normal-lane escape hatch); expired
    /// requests are shed on the way.
    pub(crate) fn pop_leader(&self, starve_after: Duration) -> Option<Pending> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            let leader =
                super::batcher::pop_leader(&mut inner.items, Instant::now(), starve_after);
            self.publish_depth(&mut inner);
            if let Some(p) = leader {
                return Some(p);
            }
            if inner.closed {
                return None;
            }
            inner = self.arrived.wait(inner).unwrap();
        }
    }

    /// One gather pass: move queued requests compatible with `key` into
    /// `batch` (high lane first, FIFO per lane), shedding any expired
    /// entry scanned, until `batch` holds `max_batch` requests.
    /// Returns the arrival generation the pass observed — the `seen`
    /// token for [`Queue::wait_for_arrival`].
    pub(crate) fn take_compatible(
        &self,
        batch: &mut Vec<Pending>,
        key: &super::batcher::BucketKey,
        max_batch: usize,
    ) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        super::batcher::take_compatible(&mut inner.items, batch, key, max_batch, Instant::now());
        self.publish_depth(&mut inner);
        inner.arrivals
    }

    /// Park until a push lands that the gather pass which observed
    /// `seen` has not scanned, or `until` passes.  The timer is
    /// authoritative: once `until` is reached this returns false even
    /// if the queue is non-empty — a backlog of foreign-bucket requests
    /// the batcher has already walked must not keep a partial batch
    /// from dispatching (those requests get their turn as the next
    /// leader).  Also returns false when the queue is closed with no
    /// unseen arrivals — the batcher then dispatches what it has.
    pub(crate) fn wait_for_arrival(&self, until: Instant, seen: u64) -> bool {
        let mut inner = self.inner.lock().unwrap();
        loop {
            let now = Instant::now();
            let Some(left) = until.checked_duration_since(now).filter(|d| !d.is_zero()) else {
                return false;
            };
            if inner.arrivals != seen {
                return true;
            }
            if inner.closed {
                return false;
            }
            let (guard, _timeout) = self.arrived.wait_timeout(inner, left).unwrap();
            inner = guard;
        }
    }

    /// Close admission (push rejects from now on), republish the depth
    /// gauges, and wake the gatherer so it drains and exits.
    pub(crate) fn close(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.closed = true;
        self.publish_depth(&mut inner);
        self.arrived.notify_all();
    }

    #[cfg(test)]
    pub(crate) fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }
}

impl Drop for Queue {
    fn drop(&mut self) {
        // abnormal-teardown path: a queue dropped with entries still
        // inside (dispatcher panic, server torn down mid-backlog) must
        // resolve those tickets (Pending::drop → Shed(Dropped)) and
        // take its contribution out of the depth gauges — otherwise a
        // dead server reports a stale nonzero serve_queue_depth forever
        let published = {
            let inner = match self.inner.get_mut() {
                Ok(inner) => inner,
                Err(poisoned) => poisoned.into_inner(),
            };
            inner.items.clear();
            std::mem::replace(&mut inner.published, 0)
        };
        let delta = -(published as isize);
        let total = self.total.fetch_add(delta, Ordering::Relaxed) + delta;
        obs::gauge_set("serve_queue_depth", total.max(0) as f64);
        obs::gauge_set(&self.depth_gauge, 0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Head, ModelKind, ShedReason, Ticket};
    use super::*;
    use crate::linalg::Matrix;

    const NO_STARVE: Duration = Duration::from_secs(3600);

    fn request(id: u64) -> Request {
        Request {
            id,
            kind: ModelKind::Exact,
            heads: vec![Head {
                q: Matrix::zeros(2, 2),
                k: Matrix::zeros(2, 2),
                v: Matrix::zeros(2, 2),
            }],
            deadline: None,
            priority: Priority::Normal,
        }
    }

    fn pending(id: u64) -> (Pending, Ticket) {
        let state = Arc::new(TicketState::default());
        (Pending::new(request(id), Arc::clone(&state)), Ticket(state))
    }

    fn gauge(name: &str) -> Option<f64> {
        match obs::snapshot().metrics.get(name) {
            Some(obs::Metric::Gauge(v)) => Some(*v),
            _ => None,
        }
    }

    #[test]
    fn bounded_push_rejects_when_full() {
        let q = Queue::new(2);
        let (p1, _t1) = pending(1);
        let (p2, _t2) = pending(2);
        let (p3, _t3) = pending(3);
        assert!(q.push(p1).is_ok());
        assert!(q.push(p2).is_ok());
        assert!(matches!(q.push(p3), Err(RejectReason::QueueFull)));
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn closed_push_rejects_shutting_down() {
        let q = Queue::new(4);
        q.close();
        let (p, _t) = pending(1);
        assert!(matches!(q.push(p), Err(RejectReason::ShuttingDown)));
    }

    #[test]
    fn pop_leader_drains_then_returns_none_when_closed() {
        let q = Queue::new(4);
        let (p, _t) = pending(7);
        q.push(p).unwrap();
        q.close();
        assert_eq!(q.pop_leader(NO_STARVE).unwrap().req.id, 7);
        assert!(q.pop_leader(NO_STARVE).is_none());
    }

    #[test]
    fn dropped_pending_resolves_ticket() {
        let (p, t) = pending(1);
        drop(p);
        match t.wait() {
            Outcome::Shed(ShedReason::Dropped) => {}
            other => panic!("expected Dropped, got {other:?}"),
        }
    }

    /// Regression for the depth-gauge staleness bug: close() and the
    /// teardown path must republish, so a shut-down (or abnormally
    /// torn-down) queue reads depth 0, not whatever the last push
    /// published.  Shard 91 is used by no other test, so the per-shard
    /// gauge is race-free even with the global registry shared across
    /// the parallel test harness.
    #[test]
    fn depth_gauge_republished_on_close_and_teardown() {
        let total = Arc::new(AtomicIsize::new(0));
        let q = Queue::for_shard(8, 91, Arc::clone(&total));
        let (p1, t1) = pending(1);
        let (p2, t2) = pending(2);
        q.push(p1).unwrap();
        q.push(p2).unwrap();
        assert_eq!(gauge("serve_shard_91_queue_depth"), Some(2.0));
        assert_eq!(total.load(Ordering::Relaxed), 2);
        q.close();
        // close republishes (still 2 queued — nothing drained them)
        assert_eq!(gauge("serve_shard_91_queue_depth"), Some(2.0));
        // abnormal teardown: queue dropped with a live backlog — the
        // gauge must go to zero, the shared counter must give the two
        // back, and both tickets must resolve (as Dropped)
        drop(q);
        assert_eq!(gauge("serve_shard_91_queue_depth"), Some(0.0));
        assert_eq!(total.load(Ordering::Relaxed), 0);
        assert!(matches!(t1.wait(), Outcome::Shed(ShedReason::Dropped)));
        assert!(matches!(t2.wait(), Outcome::Shed(ShedReason::Dropped)));
    }

    /// Graceful-drain counterpart: a queue drained through pop_leader
    /// publishes zero before it is ever dropped.
    #[test]
    fn depth_gauge_zero_after_drain() {
        let total = Arc::new(AtomicIsize::new(0));
        let q = Queue::for_shard(8, 92, Arc::clone(&total));
        let (p, _t) = pending(1);
        q.push(p).unwrap();
        assert_eq!(gauge("serve_shard_92_queue_depth"), Some(1.0));
        let _leader = q.pop_leader(NO_STARVE).unwrap();
        assert_eq!(gauge("serve_shard_92_queue_depth"), Some(0.0));
        assert_eq!(total.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn wait_for_arrival_times_out_on_empty_queue() {
        let q = Queue::new(4);
        // generous margin: correctness here is "returns false with no
        // unseen arrival", not a tight timing bound — loaded CI hosts
        // may oversleep the condvar arbitrarily
        let until = Instant::now() + Duration::from_millis(30);
        assert!(!q.wait_for_arrival(until, 0));
    }

    /// Regression for the gather-loop livelock: a backlog the batcher
    /// has already scanned (here a foreign-bucket request) must not
    /// defeat the timer — `wait_for_arrival` has to block and then
    /// report false at the deadline, not return true instantly because
    /// the queue is non-empty.  Asserted on generation semantics (the
    /// arrival counter is unchanged, the backlog is still queued), not
    /// on wall-clock margins.
    #[test]
    fn wait_for_arrival_times_out_with_only_scanned_backlog() {
        let q = Queue::new(4);
        let (p, _t) = pending(1);
        q.push(p).unwrap();
        // a gather pass for a bucket nothing matches: takes nothing,
        // observes the current arrival generation
        let foreign = super::super::batcher::BucketKey {
            kind: ModelKind::Kernelized,
            n: 2,
            m: 2,
            p: 2,
            dv: 2,
        };
        let mut batch = Vec::new();
        let seen = q.take_compatible(&mut batch, &foreign, 4);
        assert!(batch.is_empty());
        let start = Instant::now();
        let until = start + Duration::from_millis(30);
        assert!(!q.wait_for_arrival(until, seen), "stale backlog must not read as arrival");
        // the timer is authoritative: false is only returned at/after
        // `until`, so an instant return (the old hot-spin) shows up as
        // elapsed < deadline.  The bound is on the monotonic clock we
        // set the deadline with — not load-sensitive.
        assert!(start.elapsed() >= Duration::from_millis(30), "must block, not spin");
        assert_eq!(q.len(), 1, "foreign request still queued for the next leader pop");
        // generation semantics: nothing arrived while we waited — a
        // re-scan observes the same token, so the gatherer would
        // dispatch its partial batch rather than loop again
        let again = q.take_compatible(&mut batch, &foreign, 4);
        assert_eq!(again, seen, "no unseen arrival may exist after a timed-out wait");
    }

    #[test]
    fn wait_for_arrival_sees_push_after_gather() {
        let q = Queue::new(4);
        let foreign = super::super::batcher::BucketKey {
            kind: ModelKind::Kernelized,
            n: 2,
            m: 2,
            p: 2,
            dv: 2,
        };
        let seen = q.take_compatible(&mut Vec::new(), &foreign, 4);
        let (p, _t) = pending(1);
        q.push(p).unwrap();
        // the deadline is irrelevant to the semantics under test (an
        // unseen arrival returns true immediately); it is generous so a
        // loaded host cannot turn a pass into a timeout
        let until = Instant::now() + Duration::from_secs(30);
        assert!(q.wait_for_arrival(until, seen), "push after the gather pass is a new arrival");
    }
}
