//! # Skyformer — reproduction library
//!
//! Rust coordinator (Layer 3) for the Skyformer NeurIPS-2021 paper:
//! *"Skyformer: Remodel Self-Attention with Gaussian Kernel and Nyström
//! Method"* (Chen, Zeng, Ji, Yang).
//!
//! The three-layer architecture (DESIGN.md):
//!
//! * **Layer 1** — Pallas kernels (python, build time): Gaussian-kernel
//!   attention, online-softmax attention, Nyström landmark blocks,
//!   Newton–Schulz inverse.
//! * **Layer 2** — JAX model (python, build time): the LRA 2-layer
//!   transformer with 9 pluggable attention mechanisms, lowered by
//!   `python/compile/aot.py` to HLO-text artifacts.
//! * **Layer 3** — this crate: loads the artifacts via PJRT
//!   ([`runtime`]), generates the LRA workloads ([`data`]), drives
//!   training/evaluation ([`coordinator`]), and regenerates every table
//!   and figure of the paper ([`report`], `rust/benches/`, `examples/`).
//!
//! Python never runs on the request path: after `make artifacts` the
//! binary is self-contained.
//!
//! The crate also carries native-rust reference implementations of all the
//! attention mechanisms ([`attention`]) and of the modified Nyström method
//! ([`nystrom`]) on a dense f32 matrix substrate ([`linalg`]) — these power
//! the paper's matrix-approximation study (Figure 1) and the
//! property-test suite without any HLO involvement.
//!
//! The dense hot paths run on the native pallas-style kernel subsystem
//! in [`kernels`]: a scoped thread pool with deterministic
//! row-partitioned scheduling, one shared tiling implementation, and
//! fused tiled kernels (`matmul`, `matmul_transb`, `gaussian_scores`,
//! `row_softmax_matmul`, `scale_add`) that `linalg`, `attention`, and
//! `nystrom` dispatch through a `KernelCtx`.  Results are bit-identical
//! across thread counts (KERNELS.md); pick the width with
//! `SKYFORMER_THREADS=N` or `--threads N`.
//!
//! The inference request path lives in [`serve`] (SERVING.md): a
//! bounded admission queue with backpressure, a dynamic micro-batcher
//! that coalesces compatible requests by model kind + attention shape,
//! and a deadline-aware dispatcher that runs each batch — all heads of
//! all requests — as **one** kernel-pool job via the batched attention
//! kernels in [`kernels::batch`].  Batched output is bit-identical to
//! per-request dispatch, so micro-batching never costs reproducibility.
//!
//! Cross-cutting observability lives in [`obs`]: hierarchical span tracing
//! over the train step → upload/execute/download pipeline and the
//! Newton–Schulz solve, a global metrics registry (counters, gauges,
//! log-bucketed histograms), and exporters for Chrome Trace Event Format,
//! JSONL, and Prometheus text.  Enable with `SKYFORMER_TRACE=1` or
//! `--obs-out <prefix>` on the binaries; see OBSERVABILITY.md.
//!
//! PJRT execution is gated behind the `pjrt` cargo feature so the
//! native-rust layers (attention, nystrom, linalg, data, report, obs)
//! build and test fully offline; the default feature set is empty.

pub mod attention;
pub mod coordinator;
pub mod data;
pub mod kernels;
pub mod linalg;
pub mod nystrom;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod util;

pub use util::error::{Error, Result};
