//! The training loop: the Layer-3 orchestration proper.
//!
//! Owns the train state (flattened params + optimizer leaves as host
//! tensors), generates deterministic batches, schedules the LR, invokes
//! the train/eval HLO executables, tracks metrics, and keeps the best
//! checkpoint — the paper's §5 protocol ("the best checkpoint with the
//! highest accuracy on the development set will be saved for evaluation").

use std::path::PathBuf;
use std::rc::Rc;
use std::time::Instant;

use crate::coordinator::checkpoint;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::scheduler::Schedule;
use crate::data::batch::{Batch, Dataset, Split};
use crate::obs;
use crate::runtime::engine::{Engine, Executable};
use crate::runtime::tensor::Tensor;
use crate::util::error::{Error, Result};

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub task: String,
    pub attention: String,
    pub pallas: bool,
    pub steps: usize,
    pub eval_every: usize,
    pub eval_batches: usize,
    pub schedule: Schedule,
    pub seed: u64,
    pub log_every: usize,
    /// save the best checkpoint here if set
    pub checkpoint_path: Option<PathBuf>,
    pub verbose: bool,
}

impl TrainConfig {
    pub fn new(task: &str, attention: &str) -> TrainConfig {
        // paper §5: lr 1e-4 (2e-4 for retrieval/pathfinder)
        let base_lr = match task {
            "retrieval" | "pathfinder" => 2e-4,
            _ => 1e-4,
        };
        TrainConfig {
            task: task.to_string(),
            attention: attention.to_string(),
            pallas: false,
            steps: 200,
            eval_every: 50,
            eval_batches: 8,
            schedule: Schedule::Warmup { base: base_lr, warmup_steps: 20 },
            seed: 0,
            log_every: 20,
            checkpoint_path: None,
            verbose: false,
        }
    }
}

#[derive(Debug)]
pub struct TrainResult {
    pub metrics: Metrics,
    pub best_eval_acc: f32,
    pub final_eval_acc: f32,
    pub final_eval_loss: f32,
    pub test_acc: f32,
    pub total_seconds: f64,
}

pub struct Trainer {
    pub cfg: TrainConfig,
    exec_train: Rc<Executable>,
    exec_eval: Rc<Executable>,
    dataset: Dataset,
    /// flattened params + optimizer leaves, in manifest order
    pub state: Vec<Tensor>,
    best_state: Option<Vec<Tensor>>,
    pub metrics: Metrics,
}

impl Trainer {
    pub fn new(engine: &Engine, cfg: TrainConfig) -> Result<Trainer> {
        let exec_init = engine.load(&cfg.task, &cfg.attention, "init", cfg.pallas)?;
        let exec_train = engine.load(&cfg.task, &cfg.attention, "train", cfg.pallas)?;
        let exec_eval = engine.load(&cfg.task, &cfg.attention, "eval", cfg.pallas)?;
        let task = exec_train.spec.task_config.clone();
        let dataset = Dataset::for_task(&task, cfg.seed)?;
        // initialise params + optimizer in-graph, per-seed
        let state = exec_init.run(&[Tensor::scalar_u32(cfg.seed as u32)])?;
        let mut metrics = Metrics::new();
        let state_bytes: usize = state.iter().map(|t| t.size_bytes()).sum();
        metrics.observe_bytes(state_bytes + exec_train.spec.input_bytes());
        Ok(Trainer {
            cfg,
            exec_train,
            exec_eval,
            dataset,
            state,
            best_state: None,
            metrics,
        })
    }

    fn num_state(&self) -> usize {
        self.exec_train.spec.num_state()
    }

    /// One optimizer step on the `step`-th deterministic train batch.
    pub fn step(&mut self, step: usize) -> Result<(f32, f32)> {
        let _span = obs::span("train", "step");
        let batch = self.dataset.batch(Split::Train, step as u64);
        let lr = self.cfg.schedule.lr(step);
        let t0 = Instant::now();
        let (loss, acc) = self.step_on(&batch, step, lr)?;
        let wall = t0.elapsed().as_secs_f64();
        self.metrics.record_step(step, loss, acc, wall);
        obs::observe("train_step_seconds", wall);
        obs::counter_add("train_steps_total", 1);
        obs::gauge_set("train_loss", loss as f64);
        obs::gauge_set("train_acc", acc as f64);
        obs::gauge_set("train_lr", lr as f64);
        Ok((loss, acc))
    }

    /// One step on a caller-supplied batch (instability probe uses this).
    pub fn step_on(&mut self, batch: &Batch, step: usize, lr: f32) -> Result<(f32, f32)> {
        let mut inputs = Vec::with_capacity(self.num_state() + 4);
        inputs.extend(self.state.iter().cloned());
        inputs.push(batch.tokens.clone());
        inputs.push(batch.labels.clone());
        inputs.push(Tensor::scalar_u32(self.step_seed(step)));
        inputs.push(Tensor::F32 { shape: vec![], data: vec![lr] });
        let mut out = self.exec_train.run(&inputs)?;
        if out.len() != self.num_state() + 2 {
            return Err(Error::Artifact {
                name: self.exec_train.spec.name.clone(),
                message: format!("train returned {} outputs", out.len()),
            });
        }
        let acc = out.pop().unwrap().scalar_value_f32()?;
        let loss = out.pop().unwrap().scalar_value_f32()?;
        self.state = out;
        if !loss.is_finite() {
            return Err(Error::Other(format!(
                "{}/{}: non-finite loss at step {step}",
                self.cfg.task, self.cfg.attention
            )));
        }
        Ok((loss, acc))
    }

    fn step_seed(&self, step: usize) -> u32 {
        // decorrelate attention randomness across steps and runs
        (self.cfg.seed as u32)
            .wrapping_mul(0x9E37_79B9)
            .wrapping_add(step as u32)
    }

    /// Mean (loss, acc) over `n` deterministic batches of a split.
    pub fn evaluate(&self, split: Split, n: usize) -> Result<(f32, f32)> {
        self.evaluate_state(self.state(), split, n)
    }

    fn evaluate_state(&self, state: &[Tensor], split: Split, n: usize) -> Result<(f32, f32)> {
        let _span = obs::span("train", "eval");
        let n_p = self.exec_train.spec.num_params;
        let mut loss_sum = 0.0f32;
        let mut acc_sum = 0.0f32;
        for i in 0..n {
            let batch = self.dataset.batch(split, i as u64);
            let mut inputs = Vec::with_capacity(n_p + 3);
            inputs.extend(state[..n_p].iter().cloned());
            inputs.push(batch.tokens);
            inputs.push(batch.labels);
            inputs.push(Tensor::scalar_u32(1_000_000 + i as u32));
            let out = self.exec_eval.run(&inputs)?;
            loss_sum += out[0].scalar_value_f32()?;
            acc_sum += out[1].scalar_value_f32()?;
        }
        Ok((loss_sum / n as f32, acc_sum / n as f32))
    }

    pub fn state(&self) -> &[Tensor] {
        &self.state
    }

    /// Deterministic batch access for external probes (instability, SVD).
    pub fn dataset_batch(&self, split: Split, index: u64) -> Batch {
        self.dataset.batch(split, index)
    }

    /// Full training run per the paper's protocol.
    pub fn train(&mut self) -> Result<TrainResult> {
        // fresh run: drop step/eval records from earlier runs or manual
        // step() probes in this process (keeps peak_bytes — model property)
        self.metrics.reset();
        let _span = obs::span(
            "train",
            &format!("train:{}/{}", self.cfg.task, self.cfg.attention),
        );
        let start = Instant::now();
        let mut best_acc = f32::NEG_INFINITY;
        for step in 0..self.cfg.steps {
            let (loss, acc) = self.step(step)?;
            if self.cfg.verbose && step % self.cfg.log_every == 0 {
                eprintln!(
                    "[{}/{}] step {step:>5} loss {loss:.4} acc {acc:.3} lr {:.2e}",
                    self.cfg.task,
                    self.cfg.attention,
                    self.cfg.schedule.lr(step)
                );
            }
            let is_last = step + 1 == self.cfg.steps;
            if (step + 1) % self.cfg.eval_every == 0 || is_last {
                let (el, ea) = self.evaluate(Split::Valid, self.cfg.eval_batches)?;
                self.metrics.record_eval(step, el, ea);
                obs::gauge_set("eval_loss", el as f64);
                obs::gauge_set("eval_acc", ea as f64);
                if ea > best_acc {
                    best_acc = ea;
                    self.best_state = Some(self.state.clone());
                }
                if self.cfg.verbose {
                    eprintln!(
                        "[{}/{}] eval @ {step}: loss {el:.4} acc {ea:.3}",
                        self.cfg.task, self.cfg.attention
                    );
                }
            }
        }
        // test accuracy of the best checkpoint (paper protocol)
        let best = self.best_state.clone().unwrap_or_else(|| self.state.clone());
        let (_, test_acc) = self.evaluate_state(&best, Split::Test, self.cfg.eval_batches)?;
        if let Some(path) = &self.cfg.checkpoint_path {
            checkpoint::save(
                path,
                &self.exec_train.spec.inputs[..self.num_state()],
                &best,
            )?;
        }
        let last_eval = self.metrics.evals.last().cloned();
        obs::gauge_set("train_peak_bytes", self.metrics.peak_bytes as f64);
        Ok(TrainResult {
            best_eval_acc: best_acc.max(0.0),
            final_eval_acc: last_eval.as_ref().map(|e| e.acc).unwrap_or(0.0),
            final_eval_loss: last_eval.as_ref().map(|e| e.loss).unwrap_or(f32::NAN),
            test_acc,
            total_seconds: start.elapsed().as_secs_f64(),
            metrics: std::mem::take(&mut self.metrics),
        })
    }

    /// Restore state from a checkpoint file.
    pub fn restore(&mut self, path: &std::path::Path) -> Result<()> {
        let (names, tensors) = checkpoint::load(path)?;
        let want = &self.exec_train.spec.inputs[..self.num_state()];
        if names.len() != want.len() {
            return Err(Error::Other(format!(
                "checkpoint has {} tensors, artifact expects {}",
                names.len(),
                want.len()
            )));
        }
        for (name, spec) in names.iter().zip(want) {
            if name != &spec.name {
                return Err(Error::Other(format!(
                    "checkpoint tensor {name} != artifact leaf {}",
                    spec.name
                )));
            }
        }
        self.state = tensors;
        Ok(())
    }
}
