//! Checkpoints: the flattened train state (params + optimizer leaves) with
//! their manifest names, in a self-describing binary format.
//!
//! Layout: `SKYCKPT1` magic, u64 header length, JSON header
//! (`{"tensors": [{name, shape, dtype, offset_bytes}, ...]}`), then raw
//! little-endian tensor data.  No serde/npz in the offline environment —
//! this *is* the checkpoint substrate.

use std::io::{Read, Write};
use std::path::Path;

use crate::runtime::manifest::TensorSpec;
use crate::runtime::tensor::{DType, Tensor};
use crate::util::error::{Error, Result};
use crate::util::json::{self, Value};

const MAGIC: &[u8; 8] = b"SKYCKPT1";

/// Save `state` (aligned with `specs`) to `path`.
///
/// The write is atomic with respect to crashes: bytes go to `{path}.tmp`
/// first and only a successful, flushed write is renamed over `path`, so
/// a reader (or a resumed run) never observes a torn checkpoint — it sees
/// either the previous complete file or the new one.
pub fn save(path: &Path, specs: &[TensorSpec], state: &[Tensor]) -> Result<()> {
    if specs.len() != state.len() {
        return Err(Error::Other(format!(
            "checkpoint: {} specs vs {} tensors",
            specs.len(),
            state.len()
        )));
    }
    let mut entries = Vec::new();
    let mut offset = 0usize;
    for (spec, t) in specs.iter().zip(state) {
        entries.push(json::obj(vec![
            ("name", json::s(spec.name.clone())),
            (
                "shape",
                Value::Array(t.shape().iter().map(|&d| json::num(d as f64)).collect()),
            ),
            ("dtype", json::s(t.dtype().name())),
            ("offset", json::num(offset as f64)),
        ]));
        offset += t.size_bytes();
    }
    let header = json::to_string(&json::obj(vec![("tensors", Value::Array(entries))]));

    // `.tmp` lives next to the target so the rename stays on one filesystem
    let mut tmp = path.as_os_str().to_os_string();
    tmp.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp);
    let write_tmp = || -> Result<()> {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u64).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for t in state {
            let bytes: &[u8] = match t {
                Tensor::F32 { data, .. } => cast_slice(data),
                Tensor::I32 { data, .. } => cast_slice(data),
                Tensor::U32 { data, .. } => cast_slice(data),
            };
            f.write_all(bytes)?;
        }
        f.sync_all()?;
        Ok(())
    };
    if let Err(e) = write_tmp().and_then(|()| Ok(std::fs::rename(&tmp, path)?)) {
        let _ = std::fs::remove_file(&tmp); // best-effort; the error wins
        return Err(e);
    }
    Ok(())
}

/// Load a checkpoint; returns (names, tensors) in file order.
pub fn load(path: &Path) -> Result<(Vec<String>, Vec<Tensor>)> {
    let mut f = std::fs::File::open(path)?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::Other(format!("{}: not a checkpoint", path.display())));
    }
    let mut len8 = [0u8; 8];
    f.read_exact(&mut len8)?;
    let hlen = u64::from_le_bytes(len8) as usize;
    let mut hbuf = vec![0u8; hlen];
    f.read_exact(&mut hbuf)?;
    let header = json::parse(std::str::from_utf8(&hbuf).map_err(|_| {
        Error::Other("checkpoint header not utf-8".into())
    })?)?;
    let mut rest = Vec::new();
    f.read_to_end(&mut rest)?;

    let mut names = Vec::new();
    let mut tensors = Vec::new();
    for e in header
        .expect("tensors")?
        .as_array()
        .ok_or_else(|| Error::Other("tensors not an array".into()))?
    {
        let name = e.expect("name")?.as_str().unwrap_or_default().to_string();
        let shape: Vec<usize> = e
            .expect("shape")?
            .as_array()
            .unwrap_or(&[])
            .iter()
            .filter_map(|d| d.as_usize())
            .collect();
        let dtype = DType::parse(e.expect("dtype")?.as_str().unwrap_or(""))?;
        let offset = e.expect("offset")?.as_usize().unwrap_or(0);
        let n: usize = shape.iter().product();
        let bytes = rest
            .get(offset..offset + n * 4)
            .ok_or_else(|| Error::Other("checkpoint truncated".into()))?;
        let t = match dtype {
            DType::F32 => Tensor::F32 { shape, data: from_le_f32(bytes) },
            DType::I32 => Tensor::I32 { shape, data: from_le_i32(bytes) },
            DType::U32 => Tensor::U32 { shape, data: from_le_u32(bytes) },
        };
        names.push(name);
        tensors.push(t);
    }
    Ok((names, tensors))
}

fn cast_slice<T>(data: &[T]) -> &[u8] {
    unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    }
}

fn from_le_f32(b: &[u8]) -> Vec<f32> {
    b.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

fn from_le_i32(b: &[u8]) -> Vec<i32> {
    b.chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

fn from_le_u32(b: &[u8]) -> Vec<u32> {
    b.chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, shape: Vec<usize>, dtype: DType) -> TensorSpec {
        TensorSpec { name: name.into(), shape, dtype }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("skyformer_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("test.ckpt");
        let specs = vec![
            spec("params/w", vec![2, 3], DType::F32),
            spec("opt/t", vec![], DType::F32),
            spec("counts", vec![2], DType::I32),
        ];
        let state = vec![
            Tensor::from_f32(vec![2, 3], vec![1.0, -2.0, 3.5, 0.0, 1e-9, 7.0]),
            Tensor::scalar_f32(42.0),
            Tensor::from_i32(vec![2], vec![-5, 9]),
        ];
        save(&path, &specs, &state).unwrap();
        let (names, loaded) = load(&path).unwrap();
        assert_eq!(names, vec!["params/w", "opt/t", "counts"]);
        assert_eq!(loaded, state);
    }

    #[test]
    fn save_leaves_no_tmp_and_overwrites_atomically() {
        let dir = std::env::temp_dir().join("skyformer_ckpt_test_atomic");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("state.ckpt");
        let specs = vec![spec("w", vec![2], DType::F32)];
        let old = vec![Tensor::from_f32(vec![2], vec![1.0, 2.0])];
        let new = vec![Tensor::from_f32(vec![2], vec![-3.0, 4.5])];

        save(&path, &specs, &old).unwrap();
        save(&path, &specs, &new).unwrap(); // overwrite of a live checkpoint
        assert!(!dir.join("state.ckpt.tmp").exists(), "temp file left behind");
        let (_, loaded) = load(&path).unwrap();
        assert_eq!(loaded, new);
    }

    #[test]
    fn failed_save_preserves_previous_checkpoint() {
        let dir = std::env::temp_dir().join("skyformer_ckpt_test_fail");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("state.ckpt");
        let specs = vec![spec("w", vec![1], DType::F32)];
        let old = vec![Tensor::scalar_f32(7.0)];
        save(&path, &specs, &old).unwrap();

        // spec/state length mismatch errors before any byte is written
        assert!(save(&path, &specs, &[]).is_err());
        let (_, loaded) = load(&path).unwrap();
        assert_eq!(loaded, old, "failed save clobbered the previous checkpoint");
        assert!(!dir.join("state.ckpt.tmp").exists());
    }

    #[test]
    fn rejects_non_checkpoint() {
        let dir = std::env::temp_dir().join("skyformer_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn mismatched_lengths_error() {
        let dir = std::env::temp_dir().join("skyformer_ckpt_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        let specs = vec![spec("a", vec![1], DType::F32)];
        let err = save(&path, &specs, &[]);
        assert!(err.is_err());
    }
}
