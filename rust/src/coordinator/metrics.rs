//! Training metrics: per-step records, eval series, wall-clock, and the
//! peak-resident-tensor-bytes proxy Table 2's "Memory (GB)" column maps to
//! on this testbed (DESIGN.md §5).

use std::time::Instant;

use crate::util::json::{self, Value};

#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    pub acc: f32,
    pub wall_seconds: f64,
}

#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub step: usize,
    pub loss: f32,
    pub acc: f32,
    /// wall-clock seconds since training start (Figure 2/3 x-axis)
    pub at_seconds: f64,
}

#[derive(Debug)]
pub struct Metrics {
    start: Instant,
    pub steps: Vec<StepRecord>,
    pub evals: Vec<EvalRecord>,
    pub peak_bytes: usize,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            start: Instant::now(),
            steps: Vec::new(),
            evals: Vec::new(),
            peak_bytes: 0,
        }
    }

    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn record_step(&mut self, step: usize, loss: f32, acc: f32, wall_seconds: f64) {
        self.steps.push(StepRecord { step, loss, acc, wall_seconds });
    }

    pub fn record_eval(&mut self, step: usize, loss: f32, acc: f32) {
        self.evals.push(EvalRecord { step, loss, acc, at_seconds: self.elapsed() });
    }

    pub fn observe_bytes(&mut self, bytes: usize) {
        self.peak_bytes = self.peak_bytes.max(bytes);
    }

    pub fn best_eval_acc(&self) -> Option<f32> {
        self.evals.iter().map(|e| e.acc).fold(None, |m, a| {
            Some(match m {
                None => a,
                Some(b) => b.max(a),
            })
        })
    }

    pub fn final_train_loss(&self) -> Option<f32> {
        self.steps.last().map(|s| s.loss)
    }

    pub fn mean_step_seconds(&self) -> f64 {
        // the first step pays compile warm-up and must never be counted;
        // with only that step recorded there is no steady-state sample yet
        let tail: Vec<f64> = self.steps.iter().skip(1).map(|s| s.wall_seconds).collect();
        if tail.is_empty() {
            0.0
        } else {
            tail.iter().sum::<f64>() / tail.len() as f64
        }
    }

    /// Clear all records for a fresh run in the same process.  `peak_bytes`
    /// is kept: it is a property of the compiled model, not of one run.
    pub fn reset(&mut self) {
        self.start = Instant::now();
        self.steps.clear();
        self.evals.clear();
    }

    /// Serialise to JSON for EXPERIMENTS.md appendices / curve plotting.
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            (
                "steps",
                Value::Array(
                    self.steps
                        .iter()
                        .map(|s| {
                            json::obj(vec![
                                ("step", json::num(s.step as f64)),
                                ("loss", json::num(s.loss as f64)),
                                ("acc", json::num(s.acc as f64)),
                                ("wall_seconds", json::num(s.wall_seconds)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "evals",
                Value::Array(
                    self.evals
                        .iter()
                        .map(|e| {
                            json::obj(vec![
                                ("step", json::num(e.step as f64)),
                                ("loss", json::num(e.loss as f64)),
                                ("acc", json::num(e.acc as f64)),
                                ("at_seconds", json::num(e.at_seconds)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("peak_bytes", json::num(self.peak_bytes as f64)),
            ("mean_step_seconds", json::num(self.mean_step_seconds())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_eval_and_means() {
        let mut m = Metrics::new();
        m.record_step(0, 2.0, 0.1, 1.0);
        m.record_step(1, 1.5, 0.2, 0.5);
        m.record_step(2, 1.2, 0.3, 0.7);
        m.record_eval(1, 1.4, 0.25);
        m.record_eval(2, 1.1, 0.22);
        assert_eq!(m.best_eval_acc(), Some(0.25));
        assert!((m.mean_step_seconds() - 0.6).abs() < 1e-9);
        assert_eq!(m.final_train_loss(), Some(1.2));
    }

    #[test]
    fn warmup_only_step_is_never_counted() {
        let mut m = Metrics::new();
        assert_eq!(m.mean_step_seconds(), 0.0);
        m.record_step(0, 2.0, 0.1, 30.0); // compile-warm step
        assert_eq!(m.mean_step_seconds(), 0.0);
        m.record_step(1, 1.5, 0.2, 0.5);
        assert!((m.mean_step_seconds() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn reset_clears_records_keeps_peak() {
        let mut m = Metrics::new();
        m.record_step(0, 2.0, 0.1, 1.0);
        m.record_eval(0, 1.9, 0.15);
        m.observe_bytes(4096);
        m.reset();
        assert!(m.steps.is_empty());
        assert!(m.evals.is_empty());
        assert_eq!(m.peak_bytes, 4096);
        assert_eq!(m.mean_step_seconds(), 0.0);
    }

    #[test]
    fn peak_bytes_monotone() {
        let mut m = Metrics::new();
        m.observe_bytes(100);
        m.observe_bytes(50);
        m.observe_bytes(300);
        assert_eq!(m.peak_bytes, 300);
    }

    #[test]
    fn json_roundtrips() {
        let mut m = Metrics::new();
        m.record_step(0, 2.0, 0.1, 1.0);
        m.record_eval(0, 1.9, 0.15);
        let v = m.to_json();
        let text = crate::util::json::to_string(&v);
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("steps").unwrap().as_array().unwrap().len(), 1);
    }
}
