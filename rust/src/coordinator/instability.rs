//! Table-3 instability probe (paper Appendix F).
//!
//! For each model, run 20 update steps; at step i compute
//!
//!   tau_i = ||f(x_i, W_i) - f(x_i, W_{i-1})||_F^2 / ||W_i - W_{i-1}||_F^2
//!
//! where f is the two-layer encoder embedding (the `embed` artifact).
//! Table 3 reports the mean over steps of each model's tau_i divided by
//! self-attention's tau_i; ratios < 1 mean higher stability.

use std::rc::Rc;

use crate::coordinator::trainer::{TrainConfig, Trainer};
use crate::data::batch::Split;
use crate::obs;
use crate::runtime::engine::{Engine, Executable};
use crate::runtime::tensor::Tensor;
use crate::util::error::Result;
use crate::util::json;

pub struct InstabilityProbe {
    trainer: Trainer,
    exec_embed: Rc<Executable>,
}

#[derive(Debug, Clone)]
pub struct InstabilityResult {
    pub taus: Vec<f32>,
}

impl InstabilityResult {
    pub fn mean_tau(&self) -> f32 {
        self.taus.iter().sum::<f32>() / self.taus.len().max(1) as f32
    }
}

impl InstabilityProbe {
    pub fn new(engine: &Engine, mut cfg: TrainConfig) -> Result<InstabilityProbe> {
        cfg.steps = 20;
        let exec_embed = engine.load(&cfg.task, &cfg.attention, "embed", cfg.pallas)?;
        let trainer = Trainer::new(engine, cfg)?;
        Ok(InstabilityProbe { trainer, exec_embed })
    }

    fn embed(&self, params: &[Tensor], tokens: &Tensor, seed: u32) -> Result<Tensor> {
        let n_p = self.exec_embed.spec.num_params;
        let mut inputs = Vec::with_capacity(n_p + 2);
        inputs.extend(params[..n_p].iter().cloned());
        inputs.push(tokens.clone());
        inputs.push(Tensor::scalar_u32(seed));
        let mut out = self.exec_embed.run(&inputs)?;
        Ok(out.remove(0))
    }

    /// Run `steps` updates; returns tau_i per step.
    pub fn run(&mut self, steps: usize, lr: f32) -> Result<InstabilityResult> {
        let _span = obs::span("instability", "probe");
        let n_p = self.exec_embed.spec.num_params;
        let mut taus = Vec::with_capacity(steps);
        for i in 0..steps {
            let _step = obs::span("instability", "probe_step");
            let batch = self.trainer.dataset_batch(Split::Train, i as u64);
            let w_prev: Vec<Tensor> = self.trainer.state()[..n_p].to_vec();
            // fixed per-step seed so f() sees identical attention randomness
            // for W_{i-1} and W_i (tau isolates the parameter perturbation)
            let seed = 7_000 + i as u32;
            let f_prev = self.embed(&w_prev, &batch.tokens, seed)?;
            self.trainer.step_on(&batch, i, lr)?;
            let w_cur: Vec<Tensor> = self.trainer.state()[..n_p].to_vec();
            let f_cur = self.embed(&w_cur, &batch.tokens, seed)?;

            let df = sq_frobenius_diff(&[f_cur], &[f_prev])?;
            let dw = sq_frobenius_diff(&w_cur, &w_prev)?;
            let tau = df / dw.max(1e-30);
            if !tau.is_finite() {
                obs::event(
                    "instability",
                    "anomaly:non_finite_tau",
                    Some(json::obj(vec![
                        ("step", json::num(i as f64)),
                        ("df", json::num(df as f64)),
                        ("dw", json::num(dw as f64)),
                    ])),
                );
                obs::counter_add("instability_anomalies_total", 1);
            } else if dw <= 0.0 {
                // zero parameter movement: tau is meaningless for this step
                obs::event(
                    "instability",
                    "anomaly:zero_dw",
                    Some(json::obj(vec![("step", json::num(i as f64))])),
                );
                obs::counter_add("instability_anomalies_total", 1);
            } else {
                obs::event(
                    "instability",
                    "tau",
                    Some(json::obj(vec![
                        ("step", json::num(i as f64)),
                        ("tau", json::num(tau as f64)),
                    ])),
                );
            }
            taus.push(tau);
        }
        let result = InstabilityResult { taus };
        obs::gauge_set("instability_mean_tau", result.mean_tau() as f64);
        Ok(result)
    }
}

fn sq_frobenius_diff(a: &[Tensor], b: &[Tensor]) -> Result<f32> {
    let mut acc = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        let xd = x.as_f32()?;
        let yd = y.as_f32()?;
        for (p, q) in xd.iter().zip(yd) {
            let d = (p - q) as f64;
            acc += d * d;
        }
    }
    Ok(acc as f32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sq_frobenius_known() {
        let a = vec![Tensor::from_f32(vec![2], vec![1.0, 2.0])];
        let b = vec![Tensor::from_f32(vec![2], vec![0.0, 0.0])];
        assert!((sq_frobenius_diff(&a, &b).unwrap() - 5.0).abs() < 1e-6);
    }
}
