//! Training/eval orchestration over the AOT artifacts (Layer 3 proper).
pub mod checkpoint;
pub mod instability;
pub mod metrics;
pub mod scheduler;
pub mod trainer;

pub use trainer::{TrainConfig, Trainer};
