//! Training/eval orchestration over the AOT artifacts (Layer 3 proper).
//!
//! `trainer` and `instability` drive PJRT executables and are gated behind
//! the `pjrt` feature; metrics/scheduler/checkpoint are pure and always
//! available.
pub mod checkpoint;
#[cfg(feature = "pjrt")]
pub mod instability;
pub mod metrics;
pub mod scheduler;
#[cfg(feature = "pjrt")]
pub mod trainer;

#[cfg(feature = "pjrt")]
pub use trainer::{TrainConfig, Trainer};
