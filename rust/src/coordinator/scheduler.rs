//! Learning-rate schedules. The LR is a runtime input of the train-step
//! artifact, so the schedule lives entirely in the coordinator (L3) and
//! new schedules need no re-lowering.

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Schedule {
    Constant { lr: f32 },
    /// Linear warmup then constant (the LRA recipe).
    Warmup { base: f32, warmup_steps: usize },
    /// Linear warmup then cosine decay to `floor`.
    WarmupCosine { base: f32, warmup_steps: usize, total_steps: usize, floor: f32 },
}

impl Schedule {
    pub fn lr(&self, step: usize) -> f32 {
        match *self {
            Schedule::Constant { lr } => lr,
            Schedule::Warmup { base, warmup_steps } => {
                if warmup_steps == 0 || step >= warmup_steps {
                    base
                } else {
                    base * (step + 1) as f32 / warmup_steps as f32
                }
            }
            Schedule::WarmupCosine { base, warmup_steps, total_steps, floor } => {
                if step < warmup_steps {
                    return base * (step + 1) as f32 / warmup_steps.max(1) as f32;
                }
                let t = (step - warmup_steps) as f32
                    / (total_steps.saturating_sub(warmup_steps)).max(1) as f32;
                let t = t.clamp(0.0, 1.0);
                floor + 0.5 * (base - floor) * (1.0 + (std::f32::consts::PI * t).cos())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = Schedule::Constant { lr: 1e-4 };
        assert_eq!(s.lr(0), 1e-4);
        assert_eq!(s.lr(10_000), 1e-4);
    }

    #[test]
    fn warmup_ramps_then_holds() {
        let s = Schedule::Warmup { base: 1.0, warmup_steps: 10 };
        assert!((s.lr(0) - 0.1).abs() < 1e-6);
        assert!((s.lr(4) - 0.5).abs() < 1e-6);
        assert_eq!(s.lr(10), 1.0);
        assert_eq!(s.lr(99), 1.0);
    }

    #[test]
    fn cosine_decays_monotonically_to_floor() {
        let s = Schedule::WarmupCosine { base: 1.0, warmup_steps: 5, total_steps: 105, floor: 0.1 };
        let mut prev = s.lr(5);
        for step in 6..105 {
            let cur = s.lr(step);
            assert!(cur <= prev + 1e-6, "rose at {step}");
            prev = cur;
        }
        assert!((s.lr(104) - 0.1).abs() < 0.02);
        assert!((s.lr(1_000) - 0.1).abs() < 1e-6);
    }
}
