//! Table/figure renderers: emit the same rows/series the paper prints.
pub mod tables;
