//! Table rendering: aligned text tables (the same rows the paper prints)
//! plus JSON export for EXPERIMENTS.md appendices.

use crate::util::json::{self, Value};

/// A simple aligned table.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "ragged table row");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!(" {c:<w$} |", w = w));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}-|", "-".repeat(w + 2 - 1)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&line(row, &widths));
        }
        let _ = ncol;
        out
    }

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("title", json::s(self.title.clone())),
            (
                "headers",
                Value::Array(self.headers.iter().map(|h| json::s(h.clone())).collect()),
            ),
            (
                "rows",
                Value::Array(
                    self.rows
                        .iter()
                        .map(|r| Value::Array(r.iter().map(|c| json::s(c.clone())).collect()))
                        .collect(),
                ),
            ),
        ])
    }
}

/// Format seconds human-readably (s / min).
pub fn fmt_secs(s: f64) -> String {
    if s < 120.0 {
        format!("{s:.1}s")
    } else {
        format!("{:.1}min", s / 60.0)
    }
}

/// Format bytes as MB/GB.
pub fn fmt_bytes(b: usize) -> String {
    let mb = b as f64 / (1024.0 * 1024.0);
    if mb < 1024.0 {
        format!("{mb:.1}MB")
    } else {
        format!("{:.2}GB", mb / 1024.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["model", "acc"]);
        t.row(vec!["skyformer".into(), "59.4".into()]);
        t.row(vec!["sm".into(), "57.3".into()]);
        let r = t.render();
        assert!(r.contains("## Demo"));
        assert!(r.contains("| skyformer | 59.4 |"));
        assert!(r.contains("| sm        | 57.3 |"));
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only one".into()]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(fmt_secs(30.0), "30.0s");
        assert_eq!(fmt_secs(300.0), "5.0min");
        assert_eq!(fmt_bytes(10 * 1024 * 1024), "10.0MB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024 * 1024), "3.00GB");
    }
}
