//! Synthetic LRA workload generators (filled in data/*.rs).
pub mod batch;
pub mod image;
pub mod listops;
pub mod pathfinder;
pub mod retrieval;
pub mod text;

pub use batch::{Batch, Dataset, Split};
