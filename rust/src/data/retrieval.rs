//! Synthetic document-pair retrieval (the LRA/AAN substitute).
//!
//! The AAN task asks whether two long documents cite each other — i.e.
//! whether they share sparse, position-independent evidence.  We preserve
//! exactly that (DESIGN.md §5): positive pairs share a document "signature"
//! (a handful of rare byte 5-grams planted at random positions in both
//! documents); negative pairs carry different signatures.  A model must
//! match sparse features *across* two long sequences.

use crate::data::batch::ExampleGen;
use crate::runtime::manifest::TaskConfig;
use crate::util::rng::Rng;

pub struct RetrievalGen {
    seq_len: usize,
    sig_len: usize,
    sigs_per_doc: usize,
}

impl RetrievalGen {
    pub fn new(task: &TaskConfig) -> RetrievalGen {
        assert!(task.dual, "retrieval is a dual-tower task");
        RetrievalGen {
            seq_len: task.seq_len,
            sig_len: 5,
            sigs_per_doc: (task.seq_len / 64).max(2),
        }
    }

    fn fill_doc(&self, rng: &mut Rng, signature: &[Vec<i32>]) -> Vec<i32> {
        // background: random lowercase bytes
        let mut doc: Vec<i32> = (0..self.seq_len)
            .map(|_| 97 + rng.below(26) as i32)
            .collect();
        // plant each signature n-gram at a random (non-overlapping-ish) spot
        for sig in signature {
            let pos = rng.below(self.seq_len - self.sig_len);
            doc[pos..pos + self.sig_len].copy_from_slice(sig);
        }
        doc
    }

    fn random_signature(&self, rng: &mut Rng) -> Vec<Vec<i32>> {
        (0..self.sigs_per_doc)
            .map(|_| {
                // signatures use digits+punct so they are rare vs background
                (0..self.sig_len).map(|_| 33 + rng.below(26) as i32).collect()
            })
            .collect()
    }
}

impl ExampleGen for RetrievalGen {
    fn generate(&self, rng: &mut Rng) -> (Vec<i32>, i32) {
        let label = rng.below(2) as i32;
        let sig_a = self.random_signature(rng);
        let sig_b = if label == 1 {
            sig_a.clone()
        } else {
            self.random_signature(rng)
        };
        let mut toks = self.fill_doc(rng, &sig_a);
        toks.extend(self.fill_doc(rng, &sig_b));
        (toks, label)
    }

    fn name(&self) -> &'static str {
        "retrieval"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task() -> TaskConfig {
        TaskConfig {
            name: "retrieval".into(),
            seq_len: 128,
            vocab_size: 256,
            num_classes: 2,
            batch_size: 4,
            dual: true,
        }
    }

    #[test]
    fn positive_pairs_share_ngrams_negative_dont() {
        let g = RetrievalGen::new(&task());
        let shared_5grams = |a: &[i32], b: &[i32]| -> usize {
            let mut count = 0;
            for w in a.windows(5) {
                // signatures are drawn from the rare byte range 33..59
                if w.iter().all(|&t| (33..59).contains(&t))
                    && b.windows(5).any(|x| x == w)
                {
                    count += 1;
                }
            }
            count
        };
        let mut pos_ok = 0;
        let mut neg_ok = 0;
        let (mut n_pos, mut n_neg) = (0, 0);
        for s in 0..80 {
            let mut rng = Rng::new(s);
            let (toks, label) = g.generate(&mut rng);
            let (a, b) = toks.split_at(128);
            let shared = shared_5grams(a, b);
            if label == 1 {
                n_pos += 1;
                pos_ok += usize::from(shared >= 1);
            } else {
                n_neg += 1;
                neg_ok += usize::from(shared == 0);
            }
        }
        assert!(pos_ok as f32 >= 0.9 * n_pos as f32, "{pos_ok}/{n_pos}");
        assert!(neg_ok as f32 >= 0.9 * n_neg as f32, "{neg_ok}/{n_neg}");
    }

    #[test]
    fn emits_two_documents() {
        let g = RetrievalGen::new(&task());
        let mut rng = Rng::new(0);
        let (toks, _) = g.generate(&mut rng);
        assert_eq!(toks.len(), 256);
    }
}
