//! Procedural Pathfinder (the LRA/Linsley et al. substitute).
//!
//! The Pathfinder task: a 32x32 image with two endpoint dots and dashed
//! curves; the label is whether the dots are connected by one of the
//! curves.  We render exactly that structure (DESIGN.md §5): a jittered
//! lattice path between the endpoints (positive) or two disjoint dead-end
//! curves from the endpoints (negative), plus distractor dashes in both
//! classes.  Rasterised row-major to a 1024-token grayscale sequence —
//! the spatial long-range dependency the paper highlights.

use crate::data::batch::ExampleGen;
use crate::runtime::manifest::TaskConfig;
use crate::util::rng::Rng;

pub struct PathfinderGen {
    side: usize,
}

const INK: i32 = 255;
const DOT: i32 = 200;

impl PathfinderGen {
    pub fn new(task: &TaskConfig) -> PathfinderGen {
        let side = (task.seq_len as f64).sqrt() as usize;
        assert_eq!(side * side, task.seq_len, "pathfinder needs a square seq_len");
        PathfinderGen { side }
    }

    /// A jittered path from `a` toward `b`; returns visited cells.
    fn walk(&self, rng: &mut Rng, a: (usize, usize), b: (usize, usize)) -> Vec<(usize, usize)> {
        let mut cells = Vec::new();
        let (mut x, mut y) = (a.0 as i32, a.1 as i32);
        let (tx, ty) = (b.0 as i32, b.1 as i32);
        let side = self.side as i32;
        let mut guard = 0;
        while (x, y) != (tx, ty) && guard < 4 * side * side {
            guard += 1;
            cells.push((x as usize, y as usize));
            // step toward target with 25% random detour
            let dx = (tx - x).signum();
            let dy = (ty - y).signum();
            let (sx, sy) = if rng.uniform() < 0.25 {
                match rng.below(4) {
                    0 => (1, 0),
                    1 => (-1, 0),
                    2 => (0, 1),
                    _ => (0, -1),
                }
            } else if dx != 0 && (dy == 0 || rng.uniform() < 0.5) {
                (dx, 0)
            } else {
                (0, dy)
            };
            x = (x + sx).clamp(0, side - 1);
            y = (y + sy).clamp(0, side - 1);
        }
        cells.push((x as usize, y as usize));
        cells
    }

    /// Draw a cell list as a dashed stroke (2-on / 1-off).
    fn draw_dashed(&self, img: &mut [i32], cells: &[(usize, usize)]) {
        for (i, &(x, y)) in cells.iter().enumerate() {
            if i % 3 != 2 {
                img[y * self.side + x] = INK;
            }
        }
    }

    fn random_point(&self, rng: &mut Rng) -> (usize, usize) {
        (rng.below(self.side), rng.below(self.side))
    }
}

impl ExampleGen for PathfinderGen {
    fn generate(&self, rng: &mut Rng) -> (Vec<i32>, i32) {
        let label = rng.below(2) as i32;
        let side = self.side;
        let mut img = vec![0i32; side * side];

        // endpoints at least half the grid apart (long-range by construction)
        let (a, b) = loop {
            let a = self.random_point(rng);
            let b = self.random_point(rng);
            let dist = a.0.abs_diff(b.0) + a.1.abs_diff(b.1);
            if dist >= side {
                break (a, b);
            }
        };

        if label == 1 {
            let path = self.walk(rng, a, b);
            self.draw_dashed(&mut img, &path);
        } else {
            // two dead-end curves leaving the endpoints, not touching
            let mid_a = self.random_point(rng);
            let mid_b = self.random_point(rng);
            let pa = self.walk(rng, a, mid_a);
            let pb = self.walk(rng, b, mid_b);
            // truncate so they cover less ground and cannot accidentally join
            let pa = &pa[..pa.len().min(side)];
            let pb = &pb[..pb.len().min(side)];
            self.draw_dashed(&mut img, pa);
            self.draw_dashed(&mut img, pb);
        }

        // distractor dashes (both classes): short random strokes
        for _ in 0..3 {
            let s = self.random_point(rng);
            let e = self.random_point(rng);
            let cells = self.walk(rng, s, e);
            let cells = &cells[..cells.len().min(side / 2)];
            self.draw_dashed(&mut img, cells);
        }

        // endpoint dots drawn last (distinct intensity)
        img[a.1 * side + a.0] = DOT;
        img[b.1 * side + b.0] = DOT;
        (img, label)
    }

    fn name(&self) -> &'static str {
        "pathfinder"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task() -> TaskConfig {
        TaskConfig {
            name: "pathfinder".into(),
            seq_len: 1024,
            vocab_size: 256,
            num_classes: 2,
            batch_size: 4,
            dual: false,
        }
    }

    /// flood fill over inked cells (8-connected, dashes bridge 1-cell gaps
    /// via a 2-cell reach) from one dot, checking the other is reachable.
    fn connected(img: &[i32], side: usize) -> bool {
        let dots: Vec<usize> = img
            .iter()
            .enumerate()
            .filter(|(_, &v)| v == DOT)
            .map(|(i, _)| i)
            .collect();
        if dots.len() < 2 {
            return false;
        }
        let idx = |x: i64, y: i64| (y * side as i64 + x) as usize;
        let mut seen = vec![false; img.len()];
        let mut stack = vec![dots[0]];
        seen[dots[0]] = true;
        while let Some(p) = stack.pop() {
            if p == dots[1] {
                return true;
            }
            let (x, y) = ((p % side) as i64, (p / side) as i64);
            for dy in -2i64..=2 {
                for dx in -2i64..=2 {
                    let (nx, ny) = (x + dx, y + dy);
                    if nx < 0 || ny < 0 || nx >= side as i64 || ny >= side as i64 {
                        continue;
                    }
                    let q = idx(nx, ny);
                    if !seen[q] && img[q] > 0 {
                        seen[q] = true;
                        stack.push(q);
                    }
                }
            }
        }
        false
    }

    #[test]
    fn positive_examples_are_connected() {
        let g = PathfinderGen::new(&task());
        let mut checked = 0;
        for s in 0..60 {
            let mut rng = Rng::new(s);
            let (img, label) = g.generate(&mut rng);
            if label == 1 {
                assert!(connected(&img, 32), "positive not connected, seed {s}");
                checked += 1;
            }
        }
        assert!(checked > 10);
    }

    #[test]
    fn classes_differ_in_connectivity_rate() {
        // negatives may occasionally connect through distractors, but the
        // rate must be far below positives'
        let g = PathfinderGen::new(&task());
        let (mut pos_conn, mut n_pos) = (0, 0);
        let (mut neg_conn, mut n_neg) = (0, 0);
        for s in 0..120 {
            let mut rng = Rng::new(1000 + s);
            let (img, label) = g.generate(&mut rng);
            let c = connected(&img, 32);
            if label == 1 {
                n_pos += 1;
                pos_conn += usize::from(c);
            } else {
                n_neg += 1;
                neg_conn += usize::from(c);
            }
        }
        let pos_rate = pos_conn as f32 / n_pos as f32;
        let neg_rate = neg_conn as f32 / n_neg as f32;
        assert!(pos_rate > 0.95, "pos {pos_rate}");
        assert!(neg_rate < 0.5, "neg {neg_rate}");
    }

    #[test]
    fn image_is_sparse_ink() {
        let g = PathfinderGen::new(&task());
        let mut rng = Rng::new(2);
        let (img, _) = g.generate(&mut rng);
        let ink = img.iter().filter(|&&v| v > 0).count();
        assert!(ink > 10 && ink < img.len() / 4, "ink {ink}");
    }
}
