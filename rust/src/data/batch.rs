//! Dataset plumbing: deterministic, splittable synthetic LRA workloads.
//!
//! Every example is derived from `(seed, split, index)` through the
//! splittable RNG, so train/valid/test never overlap, batches are
//! reproducible across runs and machines, and the seed sweep of Table 1
//! (3 seeds) re-generates identical data per seed.

use crate::obs;
use crate::runtime::manifest::TaskConfig;
use crate::runtime::tensor::Tensor;
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Valid,
    Test,
}

impl Split {
    fn label(&self) -> u64 {
        match self {
            Split::Train => 1,
            Split::Valid => 2,
            Split::Test => 3,
        }
    }
}

/// One batch, ready to feed the train/eval artifacts.
#[derive(Debug, Clone)]
pub struct Batch {
    /// (B, N) or (B, 2, N) i32 tokens.
    pub tokens: Tensor,
    /// (B,) i32 labels.
    pub labels: Tensor,
}

/// A synthetic example generator for one LRA task.
pub trait ExampleGen: Send + Sync {
    /// Tokens for one example: `seq_len` entries, or `2 * seq_len` for
    /// dual (retrieval) tasks, plus the class label.
    fn generate(&self, rng: &mut Rng) -> (Vec<i32>, i32);
    fn name(&self) -> &'static str;
}

/// Deterministic dataset over a generator.
pub struct Dataset {
    gen: Box<dyn ExampleGen>,
    pub task: TaskConfig,
    base: Rng,
}

impl Dataset {
    pub fn new(gen: Box<dyn ExampleGen>, task: TaskConfig, seed: u64) -> Dataset {
        let base = Rng::new(seed).split_str(&task.name);
        Dataset { gen, task, base }
    }

    /// Construct the generator for a named LRA task.
    pub fn for_task(task: &TaskConfig, seed: u64) -> Result<Dataset> {
        let gen: Box<dyn ExampleGen> = match task.name.as_str() {
            "listops" => Box::new(crate::data::listops::ListOpsGen::new(task)),
            "text" => Box::new(crate::data::text::TextGen::new(task)),
            "retrieval" => Box::new(crate::data::retrieval::RetrievalGen::new(task)),
            "pathfinder" => Box::new(crate::data::pathfinder::PathfinderGen::new(task)),
            "image" => Box::new(crate::data::image::ImageGen::new(task)),
            other => return Err(Error::Config(format!("unknown task {other:?}"))),
        };
        Ok(Dataset::new(gen, task.clone(), seed))
    }

    /// The `index`-th batch of a split: fully deterministic.
    pub fn batch(&self, split: Split, index: u64) -> Batch {
        let _span = obs::span("data", "batch_gen");
        let b = self.task.batch_size;
        let n = self.task.seq_len;
        let per = if self.task.dual { 2 * n } else { n };
        let mut tokens = Vec::with_capacity(b * per);
        let mut labels = Vec::with_capacity(b);
        for e in 0..b {
            let mut rng = self
                .base
                .split(split.label())
                .split(index)
                .split(e as u64);
            let (toks, label) = self.gen.generate(&mut rng);
            debug_assert_eq!(toks.len(), per, "{} generator length", self.gen.name());
            tokens.extend_from_slice(&toks);
            labels.push(label);
        }
        let shape = if self.task.dual {
            vec![b, 2, n]
        } else {
            vec![b, n]
        };
        Batch {
            tokens: Tensor::from_i32(shape, tokens),
            labels: Tensor::from_i32(vec![b], labels),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(name: &str, seq: usize, vocab: usize, classes: usize, dual: bool) -> TaskConfig {
        TaskConfig {
            name: name.into(),
            seq_len: seq,
            vocab_size: vocab,
            num_classes: classes,
            batch_size: 4,
            dual,
        }
    }

    fn all_tasks() -> Vec<TaskConfig> {
        vec![
            task("listops", 128, 20, 10, false),
            task("text", 128, 256, 2, false),
            task("retrieval", 64, 256, 2, true),
            task("pathfinder", 1024, 256, 2, false),
            task("image", 1024, 256, 10, false),
        ]
    }

    #[test]
    fn batches_have_declared_shapes_and_ranges() {
        for tc in all_tasks() {
            let ds = Dataset::for_task(&tc, 0).unwrap();
            let b = ds.batch(Split::Train, 0);
            let want_shape: Vec<usize> = if tc.dual {
                vec![4, 2, tc.seq_len]
            } else {
                vec![4, tc.seq_len]
            };
            assert_eq!(b.tokens.shape(), want_shape.as_slice(), "{}", tc.name);
            for &t in b.tokens.as_i32().unwrap() {
                assert!((t as usize) < tc.vocab_size, "{}: token {t}", tc.name);
                assert!(t >= 0);
            }
            for &l in b.labels.as_i32().unwrap() {
                assert!((l as usize) < tc.num_classes, "{}: label {l}", tc.name);
            }
        }
    }

    #[test]
    fn deterministic_and_split_disjoint() {
        for tc in all_tasks() {
            let ds = Dataset::for_task(&tc, 7).unwrap();
            let a = ds.batch(Split::Train, 3);
            let b = ds.batch(Split::Train, 3);
            assert_eq!(a.tokens, b.tokens, "{}", tc.name);
            let c = ds.batch(Split::Valid, 3);
            assert_ne!(a.tokens, c.tokens, "{}: splits identical", tc.name);
            let d = ds.batch(Split::Train, 4);
            assert_ne!(a.tokens, d.tokens, "{}: batches identical", tc.name);
        }
    }

    #[test]
    fn labels_are_reasonably_balanced() {
        for tc in all_tasks() {
            let ds = Dataset::for_task(&tc, 3).unwrap();
            let mut counts = vec![0usize; tc.num_classes];
            for i in 0..64 {
                let b = ds.batch(Split::Train, i);
                for &l in b.labels.as_i32().unwrap() {
                    counts[l as usize] += 1;
                }
            }
            let total: usize = counts.iter().sum();
            let max = *counts.iter().max().unwrap();
            assert!(
                max < total * 3 / 4,
                "{}: degenerate label distribution {counts:?}",
                tc.name
            );
        }
    }
}
