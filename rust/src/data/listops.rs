//! ListOps generator — the exact generative grammar of Nangia & Bowman
//! (2018): nested prefix expressions over MIN / MAX / MED / SM (sum mod 10)
//! applied to digits, labelled by interpreting the expression.
//!
//! The original LRA dataset *is* a sample from this grammar, so unlike the
//! other tasks this substitution is lossless (DESIGN.md §5).
//!
//! Token map: digits 0-9 -> 0..9, [MIN -> 10, [MAX -> 11, [MED -> 12,
//! [SM -> 13, ] -> 14, PAD -> 15.

use crate::data::batch::ExampleGen;
use crate::runtime::manifest::TaskConfig;
use crate::util::rng::Rng;

pub const TOK_MIN: i32 = 10;
pub const TOK_MAX: i32 = 11;
pub const TOK_MED: i32 = 12;
pub const TOK_SM: i32 = 13;
pub const TOK_CLOSE: i32 = 14;
pub const TOK_PAD: i32 = 15;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Op {
    Min,
    Max,
    Med,
    Sm,
}

impl Op {
    fn token(&self) -> i32 {
        match self {
            Op::Min => TOK_MIN,
            Op::Max => TOK_MAX,
            Op::Med => TOK_MED,
            Op::Sm => TOK_SM,
        }
    }

    fn apply(&self, args: &[i32]) -> i32 {
        match self {
            Op::Min => *args.iter().min().unwrap(),
            Op::Max => *args.iter().max().unwrap(),
            Op::Med => {
                let mut v = args.to_vec();
                v.sort_unstable();
                v[v.len() / 2]
            }
            Op::Sm => args.iter().sum::<i32>() % 10,
        }
    }
}

enum Node {
    Leaf(i32),
    Expr(Op, Vec<Node>),
}

impl Node {
    fn eval(&self) -> i32 {
        match self {
            Node::Leaf(d) => *d,
            Node::Expr(op, kids) => {
                let vals: Vec<i32> = kids.iter().map(Node::eval).collect();
                op.apply(&vals)
            }
        }
    }

    fn tokenize(&self, out: &mut Vec<i32>) {
        match self {
            Node::Leaf(d) => out.push(*d),
            Node::Expr(op, kids) => {
                out.push(op.token());
                for k in kids {
                    k.tokenize(out);
                }
                out.push(TOK_CLOSE);
            }
        }
    }

    fn token_len(&self) -> usize {
        match self {
            Node::Leaf(_) => 1,
            Node::Expr(_, kids) => 2 + kids.iter().map(Node::token_len).sum::<usize>(),
        }
    }
}

pub struct ListOpsGen {
    seq_len: usize,
    max_depth: usize,
    max_args: usize,
}

impl ListOpsGen {
    pub fn new(task: &TaskConfig) -> ListOpsGen {
        assert!(task.vocab_size >= 16, "listops needs >= 16 vocab");
        ListOpsGen {
            seq_len: task.seq_len,
            // scale nesting with the budget: LRA's 2k sequences use depth 10
            max_depth: if task.seq_len >= 1024 { 8 } else { 5 },
            max_args: 5,
        }
    }

    fn gen_node(&self, rng: &mut Rng, depth: usize, budget: usize) -> Node {
        // P(subexpr) decays with depth; leaves when budget is tight
        if depth >= self.max_depth || budget < 5 || rng.uniform() < 0.25 + 0.1 * depth as f32 {
            return Node::Leaf(rng.below(10) as i32);
        }
        let op = match rng.below(4) {
            0 => Op::Min,
            1 => Op::Max,
            2 => Op::Med,
            _ => Op::Sm,
        };
        let n_args = 2 + rng.below(self.max_args - 1);
        let child_budget = (budget - 2) / n_args;
        let kids = (0..n_args)
            .map(|_| self.gen_node(rng, depth + 1, child_budget))
            .collect();
        Node::Expr(op, kids)
    }
}

impl ExampleGen for ListOpsGen {
    fn generate(&self, rng: &mut Rng) -> (Vec<i32>, i32) {
        // retry until the expression fits the sequence budget (no truncation:
        // a truncated expression would have a wrong label)
        loop {
            let root = Node::Expr(
                match rng.below(4) {
                    0 => Op::Min,
                    1 => Op::Max,
                    2 => Op::Med,
                    _ => Op::Sm,
                },
                (0..2 + rng.below(self.max_args - 1))
                    .map(|_| self.gen_node(rng, 1, self.seq_len / 3))
                    .collect(),
            );
            if root.token_len() > self.seq_len {
                continue;
            }
            let label = root.eval();
            let mut toks = Vec::with_capacity(self.seq_len);
            root.tokenize(&mut toks);
            toks.resize(self.seq_len, TOK_PAD);
            return (toks, label);
        }
    }

    fn name(&self) -> &'static str {
        "listops"
    }
}

/// Reference interpreter over a token stream — used by tests to confirm the
/// generator's labels (parse what we emitted and re-evaluate).
pub fn interpret_tokens(tokens: &[i32]) -> Option<i32> {
    let mut pos = 0usize;
    fn parse(tokens: &[i32], pos: &mut usize) -> Option<i32> {
        let t = *tokens.get(*pos)?;
        *pos += 1;
        if (0..10).contains(&t) {
            return Some(t);
        }
        let op = match t {
            TOK_MIN => Op::Min,
            TOK_MAX => Op::Max,
            TOK_MED => Op::Med,
            TOK_SM => Op::Sm,
            _ => return None,
        };
        let mut args = Vec::new();
        while *tokens.get(*pos)? != TOK_CLOSE {
            args.push(parse(tokens, pos)?);
        }
        *pos += 1; // consume ]
        if args.is_empty() {
            return None;
        }
        Some(op.apply(&args))
    }
    let v = parse(tokens, &mut pos)?;
    // remaining must be padding
    if tokens[pos..].iter().all(|&t| t == TOK_PAD) {
        Some(v)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(seq: usize) -> TaskConfig {
        TaskConfig {
            name: "listops".into(),
            seq_len: seq,
            vocab_size: 20,
            num_classes: 10,
            batch_size: 4,
            dual: false,
        }
    }

    #[test]
    fn labels_match_reference_interpreter() {
        let g = ListOpsGen::new(&task(128));
        for s in 0..200 {
            let mut rng = Rng::new(s);
            let (toks, label) = g.generate(&mut rng);
            assert_eq!(toks.len(), 128);
            let re = interpret_tokens(&toks).expect("generated tokens must parse");
            assert_eq!(re, label, "seed {s}");
        }
    }

    #[test]
    fn nesting_actually_occurs() {
        let g = ListOpsGen::new(&task(256));
        let mut saw_nested = false;
        for s in 0..50 {
            let mut rng = Rng::new(s);
            let (toks, _) = g.generate(&mut rng);
            // nested: an op token appearing after another op token without
            // an intervening close
            let mut depth_hit = 0;
            let mut cur = 0;
            for &t in &toks {
                if (TOK_MIN..=TOK_SM).contains(&t) {
                    cur += 1;
                    depth_hit = depth_hit.max(cur);
                } else if t == TOK_CLOSE {
                    cur -= 1;
                }
            }
            if depth_hit >= 3 {
                saw_nested = true;
                break;
            }
        }
        assert!(saw_nested, "generator never nests 3 deep");
    }

    #[test]
    fn interpreter_rejects_garbage() {
        assert_eq!(interpret_tokens(&[TOK_CLOSE]), None);
        assert_eq!(interpret_tokens(&[TOK_MIN, 1]), None); // unterminated
        assert_eq!(interpret_tokens(&[TOK_MIN, TOK_CLOSE]), None); // 0 args
    }

    #[test]
    fn known_expression() {
        // [SM 9 9 ] == 8 ; [MAX [MIN 2 7 ] 5 ] == 5
        assert_eq!(interpret_tokens(&[TOK_SM, 9, 9, TOK_CLOSE]), Some(8));
        assert_eq!(
            interpret_tokens(&[TOK_MAX, TOK_MIN, 2, 7, TOK_CLOSE, 5, TOK_CLOSE]),
            Some(5)
        );
    }
}
