//! Synthetic byte-level text classification (the LRA/IMDb substitute).
//!
//! What the LRA Text task tests is *dispersed long-range evidence*: the
//! sentiment signal of a 4k-byte review is spread across the document.
//! We reproduce that structure (DESIGN.md §5): documents are streams of
//! "words" drawn from a shared vocabulary, and each class plants its own
//! low-frequency evidence words at random positions; a classifier must
//! aggregate evidence across the whole sequence because any single window
//! is usually neutral.

use crate::data::batch::ExampleGen;
use crate::runtime::manifest::TaskConfig;
use crate::util::rng::Rng;

pub struct TextGen {
    seq_len: usize,
    /// bytes per synthetic word
    word_len: usize,
    /// how many evidence words each class plants per document (scaled by len)
    evidence_per_doc: usize,
    shared_words: Vec<Vec<i32>>,
    class_words: [Vec<Vec<i32>>; 2],
}

const SPACE: i32 = 32;

impl TextGen {
    pub fn new(task: &TaskConfig) -> TextGen {
        assert_eq!(task.num_classes, 2, "text task is binary");
        // fixed vocabularies derived from a dedicated stream so every
        // dataset seed shares the same "language"
        let mut lex = Rng::new(0xDEAD_BEEF).split_str("text-lexicon");
        let word_len = 4;
        let make_word = |rng: &mut Rng| -> Vec<i32> {
            (0..word_len).map(|_| 97 + rng.below(26) as i32).collect() // a-z
        };
        let shared_words: Vec<Vec<i32>> = (0..200).map(|_| make_word(&mut lex)).collect();
        let pos_words: Vec<Vec<i32>> = (0..12).map(|_| make_word(&mut lex)).collect();
        let neg_words: Vec<Vec<i32>> = (0..12).map(|_| make_word(&mut lex)).collect();
        TextGen {
            seq_len: task.seq_len,
            word_len,
            evidence_per_doc: (task.seq_len / 64).max(2),
            shared_words,
            class_words: [neg_words, pos_words],
        }
    }
}

impl ExampleGen for TextGen {
    fn generate(&self, rng: &mut Rng) -> (Vec<i32>, i32) {
        let label = rng.below(2) as i32;
        let n_words = self.seq_len / (self.word_len + 1);
        // choose evidence positions
        let n_ev = self.evidence_per_doc.min(n_words);
        let ev_pos = rng.choose_distinct(n_words, n_ev);
        let mut is_ev = vec![false; n_words];
        for &p in &ev_pos {
            is_ev[p] = true;
        }
        // contrarian noise: a few opposite-class words so single words
        // aren't decisive (must aggregate)
        let n_noise = (n_ev / 3).max(1);
        let noise_pos = rng.choose_distinct(n_words, n_noise);

        let mut toks = Vec::with_capacity(self.seq_len);
        for w in 0..n_words {
            let word = if is_ev[w] {
                &self.class_words[label as usize][rng.below(self.class_words[0].len())]
            } else if noise_pos.contains(&w) {
                &self.class_words[1 - label as usize][rng.below(self.class_words[0].len())]
            } else {
                &self.shared_words[rng.below(self.shared_words.len())]
            };
            toks.extend_from_slice(word);
            toks.push(SPACE);
        }
        toks.resize(self.seq_len, 0);
        (toks, label)
    }

    fn name(&self) -> &'static str {
        "text"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task() -> TaskConfig {
        TaskConfig {
            name: "text".into(),
            seq_len: 256,
            vocab_size: 256,
            num_classes: 2,
            batch_size: 4,
            dual: false,
        }
    }

    #[test]
    fn evidence_words_separate_classes() {
        // a bag-of-words count over class lexicons should classify well
        let g = TextGen::new(&task());
        let count_hits = |toks: &[i32], words: &[Vec<i32>]| -> usize {
            let mut hits = 0;
            for w in words {
                for win in toks.windows(w.len()) {
                    if win == w.as_slice() {
                        hits += 1;
                    }
                }
            }
            hits
        };
        let mut correct = 0;
        let total = 100;
        for s in 0..total {
            let mut rng = Rng::new(s);
            let (toks, label) = g.generate(&mut rng);
            let pos = count_hits(&toks, &g.class_words[1]);
            let neg = count_hits(&toks, &g.class_words[0]);
            let pred = i32::from(pos > neg);
            if pred == label {
                correct += 1;
            }
        }
        assert!(correct >= 85, "bag-of-evidence only classifies {correct}/100");
    }

    #[test]
    fn tokens_are_printable_bytes() {
        let g = TextGen::new(&task());
        let mut rng = Rng::new(1);
        let (toks, _) = g.generate(&mut rng);
        assert!(toks.iter().all(|&t| (0..256).contains(&t)));
        // mostly lowercase letters + spaces
        let alpha = toks.iter().filter(|&&t| (97..123).contains(&t)).count();
        assert!(alpha > toks.len() / 2);
    }
}
