//! Procedural 10-class image classification (the LRA/CIFAR-10 substitute).
//!
//! The LRA Image task rasterises 32x32 grayscale CIFAR images into
//! 1024-token sequences; what it tests is recovering class-dependent
//! *global 2-D statistics* from a 1-D pixel stream.  We preserve that
//! (DESIGN.md §5) with 10 procedurally distinct texture families
//! (stripe orientation/frequency, gradients, blobs, checker, rings),
//! each with per-example random phase/position/noise so the classes are
//! non-trivially separable.

use crate::data::batch::ExampleGen;
use crate::runtime::manifest::TaskConfig;
use crate::util::rng::Rng;

pub struct ImageGen {
    side: usize,
}

impl ImageGen {
    pub fn new(task: &TaskConfig) -> ImageGen {
        let side = (task.seq_len as f64).sqrt() as usize;
        assert_eq!(side * side, task.seq_len, "image needs a square seq_len");
        assert_eq!(task.num_classes, 10);
        ImageGen { side }
    }
}

fn quantize(v: f32) -> i32 {
    ((v.clamp(0.0, 1.0)) * 255.0) as i32
}

impl ExampleGen for ImageGen {
    fn generate(&self, rng: &mut Rng) -> (Vec<i32>, i32) {
        let label = rng.below(10) as i32;
        let s = self.side as f32;
        let phase = rng.uniform() * std::f32::consts::TAU;
        let freq = 1.0 + rng.uniform() * 2.0;
        let cx = rng.uniform() * s;
        let cy = rng.uniform() * s;
        let noise_amp = 0.15;
        let mut img = Vec::with_capacity(self.side * self.side);
        for y in 0..self.side {
            for x in 0..self.side {
                let (xf, yf) = (x as f32, y as f32);
                let base = match label {
                    // 0/1: horizontal vs vertical stripes
                    0 => (0.5 + 0.5 * ((yf / s * freq * 6.0) * std::f32::consts::TAU + phase).sin()),
                    1 => (0.5 + 0.5 * ((xf / s * freq * 6.0) * std::f32::consts::TAU + phase).sin()),
                    // 2/3: diagonal stripes (two orientations)
                    2 => (0.5 + 0.5 * (((xf + yf) / s * freq * 4.0) * std::f32::consts::TAU + phase).sin()),
                    3 => (0.5 + 0.5 * (((xf - yf) / s * freq * 4.0) * std::f32::consts::TAU + phase).sin()),
                    // 4/5: linear gradients (two directions)
                    4 => xf / s,
                    5 => yf / s,
                    // 6: radial rings around a random centre
                    6 => {
                        let r = ((xf - cx).powi(2) + (yf - cy).powi(2)).sqrt();
                        0.5 + 0.5 * (r / s * freq * 8.0 * std::f32::consts::TAU / 8.0 + phase).sin()
                    }
                    // 7: gaussian blob at a random centre
                    7 => {
                        let r2 = (xf - cx).powi(2) + (yf - cy).powi(2);
                        (-r2 / (2.0 * (s / 4.0).powi(2))).exp()
                    }
                    // 8: checkerboard (random cell size 3..6)
                    8 => {
                        let cell = 3 + (freq as usize % 4);
                        let c = (x / cell + y / cell) % 2;
                        c as f32
                    }
                    // 9: salt-and-pepper-ish high-frequency noise texture
                    _ => {
                        if rng.uniform() < 0.5 {
                            0.1
                        } else {
                            0.9
                        }
                    }
                };
                let noisy = base + noise_amp * (rng.uniform() - 0.5);
                img.push(quantize(noisy));
            }
        }
        (img, label)
    }

    fn name(&self) -> &'static str {
        "image"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task() -> TaskConfig {
        TaskConfig {
            name: "image".into(),
            seq_len: 1024,
            vocab_size: 256,
            num_classes: 10,
            batch_size: 4,
            dual: false,
        }
    }

    /// cheap directional-energy features
    fn features(img: &[i32], side: usize) -> [f32; 4] {
        let at = |x: usize, y: usize| img[y * side + x] as f32 / 255.0;
        let mut dx = 0.0;
        let mut dy = 0.0;
        let mut mean = 0.0;
        let mut var = 0.0;
        for y in 0..side - 1 {
            for x in 0..side - 1 {
                dx += (at(x + 1, y) - at(x, y)).abs();
                dy += (at(x, y + 1) - at(x, y)).abs();
                mean += at(x, y);
            }
        }
        let n = ((side - 1) * (side - 1)) as f32;
        mean /= n;
        for y in 0..side - 1 {
            for x in 0..side - 1 {
                var += (at(x, y) - mean).powi(2);
            }
        }
        [dx / n, dy / n, mean, var / n]
    }

    #[test]
    fn horizontal_vs_vertical_stripes_distinguishable() {
        let g = ImageGen::new(&task());
        let mut h_ratio = Vec::new();
        let mut v_ratio = Vec::new();
        for s in 0..400 {
            let mut rng = Rng::new(s);
            let (img, label) = g.generate(&mut rng);
            let f = features(&img, 32);
            if label == 0 {
                h_ratio.push(f[1] / (f[0] + 1e-5));
            } else if label == 1 {
                v_ratio.push(f[1] / (f[0] + 1e-5));
            }
        }
        assert!(h_ratio.len() > 5 && v_ratio.len() > 5);
        let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len() as f32;
        // horizontal stripes vary along y => dy >> dx; vertical the reverse
        assert!(
            mean(&h_ratio) > 2.0 * mean(&v_ratio),
            "h {} vs v {}",
            mean(&h_ratio),
            mean(&v_ratio)
        );
    }

    #[test]
    fn gradients_differ_from_stripes_in_variance() {
        let g = ImageGen::new(&task());
        let mut grad_dx = Vec::new();
        let mut stripe_dx = Vec::new();
        for s in 0..400 {
            let mut rng = Rng::new(7000 + s);
            let (img, label) = g.generate(&mut rng);
            let f = features(&img, 32);
            match label {
                4 => grad_dx.push(f[0]),
                1 => stripe_dx.push(f[0]),
                _ => {}
            }
        }
        let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len() as f32;
        // a smooth gradient has far less local dx energy than stripes
        assert!(mean(&grad_dx) < 0.5 * mean(&stripe_dx));
    }

    #[test]
    fn pixel_range_valid() {
        let g = ImageGen::new(&task());
        let mut rng = Rng::new(5);
        let (img, _) = g.generate(&mut rng);
        assert!(img.iter().all(|&v| (0..256).contains(&v)));
    }
}
