//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! This is the only bridge between the rust coordinator and the
//! python-authored compute graphs.  Interchange is HLO **text** (see
//! `python/compile/aot.py` and DESIGN.md §2): `HloModuleProto::from_text_file`
//! reassigns instruction ids, sidestepping the 64-bit-id protos that
//! xla_extension 0.5.1 rejects.
//!
//! * [`tensor`] — host-side tensors (f32/i32/u32) ⇄ `xla::Literal`
//! * [`manifest`] — typed view of `artifacts/manifest.json`
//! * [`engine`] — PJRT client + compiled-executable cache + typed `run`

#[cfg(feature = "pjrt")]
pub mod engine;
pub mod manifest;
pub mod tensor;

#[cfg(feature = "pjrt")]
pub use engine::{Engine, Executable};
pub use manifest::{ArtifactSpec, Manifest, TensorSpec};
pub use tensor::{DType, Tensor};
