//! Typed view of `artifacts/manifest.json` — the contract between
//! `python/compile/aot.py` (which writes it) and the coordinator (which
//! feeds executables positionally and checkpoints parameters by name).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::runtime::tensor::DType;
use crate::util::error::{Error, Result};
use crate::util::json::{self, Value};

/// One named tensor in an artifact signature.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    fn from_json(v: &Value) -> Result<TensorSpec> {
        let name = v.expect("name")?.as_str().unwrap_or_default().to_string();
        let shape = v
            .expect("shape")?
            .as_array()
            .ok_or_else(|| Error::Manifest("shape not an array".into()))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| Error::Manifest("bad dim".into())))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::parse(
            v.expect("dtype")?
                .as_str()
                .ok_or_else(|| Error::Manifest("dtype not a string".into()))?,
        )?;
        Ok(TensorSpec { name, shape, dtype })
    }

    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// LRA task shape parameters (mirrors python `configs.TaskConfig`).
#[derive(Debug, Clone, PartialEq)]
pub struct TaskConfig {
    pub name: String,
    pub seq_len: usize,
    pub vocab_size: usize,
    pub num_classes: usize,
    pub batch_size: usize,
    pub dual: bool,
}

/// Model/attention settings (mirrors python `configs.ModelConfig`).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub attention: String,
    pub emb_dim: usize,
    pub ffn_dim: usize,
    pub num_heads: usize,
    pub num_layers: usize,
    pub num_features: usize,
    pub ns_iters: usize,
    pub pallas: bool,
}

/// One lowered step function.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub kind: String, // init | train | eval | embed
    pub task: String,
    pub attention: String,
    pub pallas: bool,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub num_params: usize,
    pub num_opt: usize,
    pub task_config: TaskConfig,
    pub model_config: ModelConfig,
}

impl ArtifactSpec {
    /// Number of leading state tensors (params + optimizer) in the
    /// train-step signature.
    pub fn num_state(&self) -> usize {
        self.num_params + self.num_opt
    }

    /// Total bytes of one set of inputs — the "peak memory" proxy Table 2
    /// reports per model.
    pub fn input_bytes(&self) -> usize {
        self.inputs.iter().map(|s| s.num_elements() * 4).sum()
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Manifest(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        let root = json::parse(&text)?;
        let mut artifacts = BTreeMap::new();
        let arts = root
            .expect("artifacts")?
            .as_object()
            .ok_or_else(|| Error::Manifest("artifacts not an object".into()))?;
        for (name, v) in arts {
            artifacts.insert(name.clone(), Self::artifact_from_json(name, v)?);
        }
        Ok(Manifest { dir, artifacts })
    }

    fn artifact_from_json(name: &str, v: &Value) -> Result<ArtifactSpec> {
        let specs = |key: &str| -> Result<Vec<TensorSpec>> {
            v.expect(key)?
                .as_array()
                .ok_or_else(|| Error::Manifest(format!("{key} not an array")))?
                .iter()
                .map(TensorSpec::from_json)
                .collect()
        };
        let tc = v.expect("task_config")?;
        let mc = v.expect("model_config")?;
        let get_str = |val: &Value, key: &str| -> Result<String> {
            Ok(val.expect(key)?.as_str().unwrap_or_default().to_string())
        };
        let get_usize = |val: &Value, key: &str| -> Result<usize> {
            val.expect(key)?
                .as_usize()
                .ok_or_else(|| Error::Manifest(format!("{key} not a number")))
        };
        Ok(ArtifactSpec {
            name: name.to_string(),
            file: get_str(v, "file")?,
            kind: get_str(v, "kind")?,
            task: get_str(v, "task")?,
            attention: get_str(v, "attention")?,
            pallas: v.get("pallas").and_then(|b| b.as_bool()).unwrap_or(false),
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
            num_params: get_usize(v, "num_params")?,
            num_opt: get_usize(v, "num_opt")?,
            task_config: TaskConfig {
                name: get_str(tc, "name")?,
                seq_len: get_usize(tc, "seq_len")?,
                vocab_size: get_usize(tc, "vocab_size")?,
                num_classes: get_usize(tc, "num_classes")?,
                batch_size: get_usize(tc, "batch_size")?,
                dual: tc.get("dual").and_then(|b| b.as_bool()).unwrap_or(false),
            },
            model_config: ModelConfig {
                attention: get_str(mc, "attention")?,
                emb_dim: get_usize(mc, "emb_dim")?,
                ffn_dim: get_usize(mc, "ffn_dim")?,
                num_heads: get_usize(mc, "num_heads")?,
                num_layers: get_usize(mc, "num_layers")?,
                num_features: get_usize(mc, "num_features")?,
                ns_iters: get_usize(mc, "ns_iters")?,
                pallas: mc.get("pallas").and_then(|b| b.as_bool()).unwrap_or(false),
            },
        })
    }

    /// Look up the artifact for a (task, attention, kind) triple.
    pub fn find(&self, task: &str, attention: &str, kind: &str, pallas: bool) -> Result<&ArtifactSpec> {
        let stem = if pallas {
            format!("{task}_{attention}_pallas.{kind}")
        } else {
            format!("{task}_{attention}.{kind}")
        };
        self.artifacts.get(&stem).ok_or_else(|| {
            Error::Manifest(format!(
                "artifact {stem} not built; run `make artifacts` (or aot.py --tasks {task} --attentions {attention})"
            ))
        })
    }

    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    /// All (task, attention) pairs with a complete train/eval/init triple.
    pub fn trainable_configs(&self) -> Vec<(String, String, bool)> {
        let mut out = Vec::new();
        for spec in self.artifacts.values() {
            if spec.kind == "train" {
                let has = |kind: &str| {
                    self.find(&spec.task, &spec.attention, kind, spec.pallas).is_ok()
                };
                if has("init") && has("eval") {
                    out.push((spec.task.clone(), spec.attention.clone(), spec.pallas));
                }
            }
        }
        out.sort();
        out.dedup();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest_json() -> &'static str {
        r#"{
          "artifacts": {
            "listops_skyformer.train": {
              "name": "listops_skyformer.train",
              "file": "listops_skyformer.train.hlo.txt",
              "kind": "train",
              "task": "listops",
              "attention": "skyformer",
              "pallas": false,
              "inputs": [
                {"name": "params['embed']", "shape": [20, 64], "dtype": "f32"},
                {"name": "tokens", "shape": [32, 256], "dtype": "i32"}
              ],
              "outputs": [
                {"name": "params['embed']", "shape": [20, 64], "dtype": "f32"},
                {"name": "loss", "shape": [], "dtype": "f32"}
              ],
              "num_params": 1,
              "num_opt": 0,
              "task_config": {"name": "listops", "seq_len": 256, "vocab_size": 20,
                              "num_classes": 10, "batch_size": 32, "dual": false},
              "model_config": {"attention": "skyformer", "emb_dim": 64, "ffn_dim": 128,
                               "num_heads": 2, "num_layers": 2, "num_features": 128,
                               "ns_iters": 6, "gamma": 0.001, "block_size": 32,
                               "pallas": false}
            }
          }
        }"#
    }

    #[test]
    fn parses_sample() {
        let dir = std::env::temp_dir().join("skyformer_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), sample_manifest_json()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let spec = m.find("listops", "skyformer", "train", false).unwrap();
        assert_eq!(spec.inputs.len(), 2);
        assert_eq!(spec.inputs[0].shape, vec![20, 64]);
        assert_eq!(spec.inputs[0].dtype, DType::F32);
        assert_eq!(spec.task_config.seq_len, 256);
        assert_eq!(spec.model_config.num_features, 128);
        assert_eq!(spec.input_bytes(), 20 * 64 * 4 + 32 * 256 * 4);
        assert!(m.find("listops", "skyformer", "eval", false).is_err());
    }

    #[test]
    fn missing_manifest_is_friendly() {
        let err = Manifest::load("/nonexistent/dir").unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }
}
