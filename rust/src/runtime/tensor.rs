//! Host tensors and conversion to/from `xla::Literal`.
//!
//! The coordinator's state (parameters, optimizer moments, batches) lives
//! in these; the engine converts at the execute boundary.  Only the three
//! dtypes the artifacts use (f32 / i32 / u32) are supported — the manifest
//! guarantees nothing else appears.

use crate::util::error::{Error, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    pub fn parse(name: &str) -> Result<DType> {
        match name {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            "u32" => Ok(DType::U32),
            other => Err(Error::Manifest(format!("unsupported dtype {other:?}"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
            DType::U32 => "u32",
        }
    }

    pub fn size_bytes(&self) -> usize {
        4
    }
}

/// A host tensor: shape + typed storage.
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
    U32 { shape: Vec<usize>, data: Vec<u32> },
}

impl Tensor {
    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn scalar_u32(v: u32) -> Tensor {
        Tensor::U32 { shape: vec![], data: vec![v] }
    }

    pub fn from_f32(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::F32 { shape, data }
    }

    pub fn from_i32(shape: Vec<usize>, data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::I32 { shape, data }
    }

    pub fn zeros(dtype: DType, shape: Vec<usize>) -> Tensor {
        let n: usize = shape.iter().product();
        match dtype {
            DType::F32 => Tensor::F32 { shape, data: vec![0.0; n] },
            DType::I32 => Tensor::I32 { shape, data: vec![0; n] },
            DType::U32 => Tensor::U32 { shape, data: vec![0; n] },
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32 { .. } => DType::F32,
            Tensor::I32 { .. } => DType::I32,
            Tensor::U32 { .. } => DType::U32,
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } | Tensor::U32 { shape, .. } => {
                shape
            }
        }
    }

    pub fn len(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn size_bytes(&self) -> usize {
        self.len() * 4
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            other => Err(Error::Shape {
                expected: "f32".into(),
                got: other.dtype().name().into(),
            }),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            other => Err(Error::Shape {
                expected: "f32".into(),
                got: other.dtype().name().into(),
            }),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            other => Err(Error::Shape {
                expected: "i32".into(),
                got: other.dtype().name().into(),
            }),
        }
    }

    pub fn scalar_value_f32(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            return Err(Error::Shape {
                expected: "scalar".into(),
                got: format!("{:?}", self.shape()),
            });
        }
        Ok(d[0])
    }

    /// Convert to an `xla::Literal` (host copy).
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let (ty, bytes): (xla::ElementType, &[u8]) = match self {
            Tensor::F32 { data, .. } => (xla::ElementType::F32, bytemuck_cast(data)),
            Tensor::I32 { data, .. } => (xla::ElementType::S32, bytemuck_cast(data)),
            Tensor::U32 { data, .. } => (xla::ElementType::U32, bytemuck_cast(data)),
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            ty,
            self.shape(),
            bytes,
        )?)
    }

    /// Convert from an `xla::Literal` (host copy).
    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::F32 {
                shape: dims,
                data: lit.to_vec::<f32>()?,
            }),
            xla::ElementType::S32 => Ok(Tensor::I32 {
                shape: dims,
                data: lit.to_vec::<i32>()?,
            }),
            xla::ElementType::U32 => Ok(Tensor::U32 {
                shape: dims,
                data: lit.to_vec::<u32>()?,
            }),
            other => Err(Error::Other(format!("unsupported literal type {other:?}"))),
        }
    }
}

/// Reinterpret a 4-byte-element slice as bytes (little-endian host layout,
/// which is what PJRT CPU expects).
#[cfg(feature = "pjrt")]
fn bytemuck_cast<T>(data: &[T]) -> &[u8] {
    unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_len() {
        let t = Tensor::from_f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.size_bytes(), 24);
    }

    #[test]
    fn scalar_roundtrip_value() {
        let t = Tensor::scalar_f32(3.25);
        assert_eq!(t.scalar_value_f32().unwrap(), 3.25);
        assert!(Tensor::from_f32(vec![2], vec![1.0, 2.0])
            .scalar_value_f32()
            .is_err());
    }

    #[test]
    fn dtype_mismatch_errors() {
        let t = Tensor::scalar_u32(1);
        assert!(t.as_f32().is_err());
        assert!(t.as_i32().is_err());
    }

    #[test]
    #[cfg(feature = "pjrt")]
    fn literal_roundtrip() {
        // literal ops are host-only; works against the stub too
        let t = Tensor::from_f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);

        let ti = Tensor::from_i32(vec![3], vec![-1, 0, 7]);
        let back = Tensor::from_literal(&ti.to_literal().unwrap()).unwrap();
        assert_eq!(ti, back);

        let tu = Tensor::scalar_u32(42);
        let back = Tensor::from_literal(&tu.to_literal().unwrap()).unwrap();
        assert_eq!(tu, back);
    }
}
