//! PJRT engine: compile-once executable cache + typed execution.
//!
//! `Engine` owns the PJRT CPU client and a cache of compiled executables
//! keyed by artifact name; `Executable::run` validates input tensors
//! against the manifest signature, converts to literals, executes, and
//! unpacks the output tuple.
//!
//! Perf note (§Perf L3): inputs are passed as `Literal`s, which PJRT
//! copies to device buffers internally.  On the CPU client this copy is
//! the dominant coordinator-side cost for large batches; `run_buffers`
//! keeps state device-resident between steps (`execute_b`) so the training
//! loop only uploads the small per-step tensors (tokens/labels/seed/lr).

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use crate::obs;
use crate::runtime::manifest::{ArtifactSpec, Manifest};
use crate::runtime::tensor::Tensor;
use crate::util::error::{Error, Result};

/// A compiled artifact, ready to execute.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    /// cumulative execute statistics (perf accounting)
    pub stats: RefCell<ExecStats>,
}

#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub calls: usize,
    pub exec_seconds: f64,
    pub upload_seconds: f64,
    pub download_seconds: f64,
}

impl Executable {
    /// Validate inputs against the manifest signature.
    fn check_inputs(&self, inputs: &[Tensor]) -> Result<()> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(Error::Artifact {
                name: self.spec.name.clone(),
                message: format!(
                    "expected {} inputs, got {}",
                    self.spec.inputs.len(),
                    inputs.len()
                ),
            });
        }
        for (t, s) in inputs.iter().zip(&self.spec.inputs) {
            if t.shape() != s.shape.as_slice() || t.dtype() != s.dtype {
                return Err(Error::Shape {
                    expected: format!("{}: {:?} {}", s.name, s.shape, s.dtype.name()),
                    got: format!("{:?} {}", t.shape(), t.dtype().name()),
                });
            }
        }
        Ok(())
    }

    /// Execute with host tensors; returns host tensors (the output tuple,
    /// flattened in manifest order).
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.check_inputs(inputs)?;
        let mut stats = self.stats.borrow_mut();
        let _run = obs::span("runtime", &format!("run:{}", self.spec.name));

        let t0 = Instant::now();
        let literals: Vec<xla::Literal> = {
            let _s = obs::span("runtime", "upload");
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?
        };
        let upload = t0.elapsed().as_secs_f64();
        stats.upload_seconds += upload;
        obs::observe("runtime_upload_seconds", upload);

        let t1 = Instant::now();
        let result = {
            let _s = obs::span("runtime", "execute");
            self.exe.execute::<xla::Literal>(&literals)?
        };
        let exec = t1.elapsed().as_secs_f64();
        stats.exec_seconds += exec;
        obs::observe("runtime_exec_seconds", exec);

        let t2 = Instant::now();
        let out = {
            let _s = obs::span("runtime", "download");
            Self::unpack(&self.spec, &result)?
        };
        let download = t2.elapsed().as_secs_f64();
        stats.download_seconds += download;
        obs::observe("runtime_download_seconds", download);
        stats.calls += 1;
        obs::counter_add("runtime_calls_total", 1);
        Ok(out)
    }

    /// Execute with device-resident buffers (state stays on device).
    /// `host_inputs` are uploaded fresh; positions come from `host_index`.
    pub fn run_buffers(
        &self,
        buffers: &[xla::PjRtBuffer],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let mut stats = self.stats.borrow_mut();
        let t1 = Instant::now();
        let mut result = {
            let _s = obs::span("runtime", "execute");
            self.exe.execute_b::<xla::PjRtBuffer>(buffers)?
        };
        let exec = t1.elapsed().as_secs_f64();
        stats.exec_seconds += exec;
        obs::observe("runtime_exec_seconds", exec);
        stats.calls += 1;
        obs::counter_add("runtime_calls_total", 1);
        // single-device: one replica, whose outputs are the tuple elements
        if result.len() != 1 {
            return Err(Error::Artifact {
                name: self.spec.name.clone(),
                message: format!("expected 1 replica, got {}", result.len()),
            });
        }
        Ok(result.remove(0))
    }

    fn unpack(spec: &ArtifactSpec, result: &[Vec<xla::PjRtBuffer>]) -> Result<Vec<Tensor>> {
        let buffers = result
            .first()
            .ok_or_else(|| Error::Artifact {
                name: spec.name.clone(),
                message: "empty result".into(),
            })?;
        let mut out = Vec::with_capacity(spec.outputs.len());
        if buffers.len() == 1 && spec.outputs.len() > 1 {
            // return_tuple=True lowers everything into a single tuple buffer
            let lit = buffers[0].to_literal_sync()?;
            let parts = lit.to_tuple()?;
            if parts.len() != spec.outputs.len() {
                return Err(Error::Artifact {
                    name: spec.name.clone(),
                    message: format!(
                        "tuple arity {} != manifest outputs {}",
                        parts.len(),
                        spec.outputs.len()
                    ),
                });
            }
            for p in &parts {
                out.push(Tensor::from_literal(p)?);
            }
        } else {
            for b in buffers {
                let lit = b.to_literal_sync()?;
                // a 1-output artifact may still be a 1-tuple
                match lit.shape()? {
                    xla::Shape::Tuple(_) => {
                        for p in lit.to_tuple()? {
                            out.push(Tensor::from_literal(&p)?);
                        }
                    }
                    _ => out.push(Tensor::from_literal(&lit)?),
                }
            }
        }
        Ok(out)
    }
}

/// The PJRT client + executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Engine {
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Upload a host tensor to a device buffer.
    pub fn upload(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        let _s = obs::span("runtime", "upload");
        let t0 = Instant::now();
        let buf = match t {
            Tensor::F32 { shape, data } => {
                self.client.buffer_from_host_buffer::<f32>(data, shape, None)?
            }
            Tensor::I32 { shape, data } => {
                self.client.buffer_from_host_buffer::<i32>(data, shape, None)?
            }
            Tensor::U32 { shape, data } => {
                self.client.buffer_from_host_buffer::<u32>(data, shape, None)?
            }
        };
        obs::observe("runtime_upload_seconds", t0.elapsed().as_secs_f64());
        Ok(buf)
    }

    /// Download a device buffer to a host tensor.
    pub fn download(&self, b: &xla::PjRtBuffer) -> Result<Tensor> {
        let _s = obs::span("runtime", "download");
        let t0 = Instant::now();
        let lit = b.to_literal_sync()?;
        let t = Tensor::from_literal(&lit)?;
        obs::observe("runtime_download_seconds", t0.elapsed().as_secs_f64());
        Ok(t)
    }

    /// Load + compile (cached) the artifact for (task, attention, kind).
    pub fn load(
        &self,
        task: &str,
        attention: &str,
        kind: &str,
        pallas: bool,
    ) -> Result<Rc<Executable>> {
        let spec = self.manifest.find(task, attention, kind, pallas)?.clone();
        self.load_spec(spec)
    }

    /// Load + compile (cached) by explicit spec.
    pub fn load_spec(&self, spec: ArtifactSpec) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(&spec.name) {
            return Ok(e.clone());
        }
        let path = self.manifest.path_of(&spec);
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let executable = Rc::new(Executable {
            spec: spec.clone(),
            exe,
            stats: RefCell::new(ExecStats::default()),
        });
        self.cache
            .borrow_mut()
            .insert(spec.name, executable.clone());
        Ok(executable)
    }
}
