//! Minimal JSON parser + writer (serde is unavailable offline).
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json`,
//! checkpoint metadata and the report emitters: objects (insertion-ordered),
//! arrays, strings with escapes, f64 numbers, bools, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::error::{Error, Result};

/// A JSON value. Objects preserve no duplicate keys; lookup is by map.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Object field access; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_object().and_then(|m| m.get(key))
    }

    /// Required-field access with a manifest-flavoured error.
    pub fn expect(&self, key: &str) -> Result<&Value> {
        self.get(key)
            .ok_or_else(|| Error::Manifest(format!("missing field {key:?}")))
    }
}

pub fn parse(text: &str) -> Result<Value> {
    let bytes = text.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, message: &str) -> Error {
        Error::Json {
            offset: self.pos,
            message: message.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, want: u8) -> Result<()> {
        match self.bump() {
            Some(b) if b == want => Ok(()),
            _ => Err(self.err(&format!("expected {:?}", want as char))),
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect_byte(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect_byte(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pairs
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            self.expect_byte(b'\\')?;
                            self.expect_byte(b'u')?;
                            let lo = self.hex4()?;
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("bad surrogate"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // re-assemble UTF-8 multibyte sequences
                    let len = utf8_len(b);
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (b as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

/// Serialize a [`Value`] to compact JSON text.
pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(&mut s, v);
    s
}

fn write_value(out: &mut String, v: &Value) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Number(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Value::String(s) => write_string(out, s),
        Value::Array(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(out, item);
            }
            out.push(']');
        }
        Value::Object(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(out, k);
                out.push(':');
                write_value(out, val);
            }
            out.push('}');
        }
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders used by the report emitters.
pub fn obj(fields: Vec<(&str, Value)>) -> Value {
    Value::Object(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Number(n)
}

pub fn s(text: impl Into<String>) -> Value {
    Value::String(text.into())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse(" -12.5e2 ").unwrap(), Value::Number(-1250.0));
        assert_eq!(parse(r#""a\nb""#).unwrap(), Value::String("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_array().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn parses_unicode_escapes() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"m":{"x":[1,2.5,"s",true,null]},"n":-3}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&to_string(&v)).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse(r#"{"a": 1} extra"#).is_err());
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse(r#""héllo — ∞""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo — ∞"));
    }
}
