//! Micro-benchmark timer (criterion is unavailable offline).
//!
//! Warms up, runs timed iterations until a wall budget or iteration cap,
//! reports mean / p50 / p95 / min.  Used by every `rust/benches/*.rs`
//! harness (`cargo bench` with `harness = false`).

use std::time::{Duration, Instant};

use crate::obs;
use crate::util::json::{self, Value};

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl Stats {
    pub fn mean_ms(&self) -> f64 {
        self.mean.as_secs_f64() * 1e3
    }

    /// JSON row for bench artifacts (`BENCH_*.json`).
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("name", json::s(self.name.as_str())),
            ("iters", json::num(self.iters as f64)),
            ("mean_seconds", json::num(self.mean.as_secs_f64())),
            ("p50_seconds", json::num(self.p50.as_secs_f64())),
            ("p95_seconds", json::num(self.p95.as_secs_f64())),
            ("min_seconds", json::num(self.min.as_secs_f64())),
        ])
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>6} iters  mean {:>10.3?}  p50 {:>10.3?}  p95 {:>10.3?}  min {:>10.3?}",
            self.name, self.iters, self.mean, self.p50, self.p95, self.min
        )
    }
}

/// Benchmark `f`, spending roughly `budget` wall time after 2 warmup calls.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> Stats {
    f();
    f(); // warmup
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget || samples.len() < 5 {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
        if samples.len() >= 10_000 {
            break;
        }
    }
    // feed the samples into the obs registry so bench artifacts can embed
    // the same log-bucketed distribution the trainer exports
    let hist_name = format!("bench_{name}_seconds");
    for s in &samples {
        obs::observe(&hist_name, s.as_secs_f64());
    }
    samples.sort_unstable();
    let total: Duration = samples.iter().sum();
    Stats {
        name: name.to_string(),
        iters: samples.len(),
        mean: total / samples.len() as u32,
        p50: samples[samples.len() / 2],
        p95: samples[(samples.len() * 95 / 100).min(samples.len() - 1)],
        min: samples[0],
    }
}

/// One-shot timing of a closure returning a value.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produces_sane_stats() {
        let s = bench("noop", Duration::from_millis(20), || {
            std::hint::black_box(1 + 1);
        });
        assert!(s.iters >= 5);
        assert!(s.min <= s.p50 && s.p50 <= s.p95);
    }

    #[test]
    fn stats_json_has_all_fields() {
        let s = bench("json_smoke", Duration::from_millis(5), || {
            std::hint::black_box(1 + 1);
        });
        let v = s.to_json();
        assert_eq!(v.get("name").unwrap().as_str(), Some("json_smoke"));
        for key in ["iters", "mean_seconds", "p50_seconds", "p95_seconds", "min_seconds"] {
            assert!(v.get(key).unwrap().as_f64().is_some(), "{key}");
        }
    }

    #[test]
    fn time_once_returns_value() {
        let (v, d) = time_once(|| 40 + 2);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0);
    }
}
