//! Crate-wide error type.
//!
//! Wraps xla/PJRT failures (behind the `pjrt` feature), artifact/manifest
//! problems and IO so the coordinator can surface one uniform `Result`.
//! Display/Error impls are hand-rolled — no proc-macro dependencies in the
//! offline build.

use std::fmt;

#[derive(Debug)]
pub enum Error {
    #[cfg(feature = "pjrt")]
    Xla(xla::Error),

    Io(std::io::Error),

    Json { offset: usize, message: String },

    Manifest(String),

    Artifact { name: String, message: String },

    Shape { expected: String, got: String },

    Config(String),

    Other(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            #[cfg(feature = "pjrt")]
            Error::Xla(e) => write!(f, "xla/pjrt: {e}"),
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Json { offset, message } => {
                write!(f, "json parse error at byte {offset}: {message}")
            }
            Error::Manifest(m) => write!(f, "manifest: {m}"),
            Error::Artifact { name, message } => write!(f, "artifact {name}: {message}"),
            Error::Shape { expected, got } => {
                write!(f, "shape mismatch: expected {expected}, got {got}")
            }
            Error::Config(m) => write!(f, "config: {m}"),
            Error::Other(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            #[cfg(feature = "pjrt")]
            Error::Xla(e) => Some(e),
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    pub fn other(msg: impl Into<String>) -> Self {
        Error::Other(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = Error::Shape { expected: "f32".into(), got: "i32".into() };
        assert_eq!(format!("{e}"), "shape mismatch: expected f32, got i32");
        let e = Error::Json { offset: 7, message: "bad".into() };
        assert!(format!("{e}").contains("byte 7"));
    }

    #[test]
    fn io_source_preserved() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(std::error::Error::source(&e).is_some());
    }
}
