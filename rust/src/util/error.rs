//! Crate-wide error type.
//!
//! Wraps xla/PJRT failures, artifact/manifest problems and IO so the
//! coordinator can surface one uniform `Result`.

use thiserror::Error;

#[derive(Error, Debug)]
pub enum Error {
    #[error("xla/pjrt: {0}")]
    Xla(#[from] xla::Error),

    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    #[error("json parse error at byte {offset}: {message}")]
    Json { offset: usize, message: String },

    #[error("manifest: {0}")]
    Manifest(String),

    #[error("artifact {name}: {message}")]
    Artifact { name: String, message: String },

    #[error("shape mismatch: expected {expected}, got {got}")]
    Shape { expected: String, got: String },

    #[error("config: {0}")]
    Config(String),

    #[error("{0}")]
    Other(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    pub fn other(msg: impl Into<String>) -> Self {
        Error::Other(msg.into())
    }
}
