//! Tiny CLI-argument helper (clap is unavailable offline).
//!
//! Flags are `--name value` or `--name=value`; boolean flags are bare
//! `--name`.  Positional args are whatever remains, in order.

use std::collections::BTreeMap;

use crate::util::error::{Error, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.flags.insert(body.to_string(), v);
                } else {
                    args.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name} expects an integer, got {v:?}"))),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name} expects an integer, got {v:?}"))),
        }
    }

    pub fn get_f32(&self, name: &str, default: f32) -> Result<f32> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name} expects a float, got {v:?}"))),
        }
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated list flag.
    pub fn get_list(&self, name: &str) -> Option<Vec<String>> {
        self.get(name)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn mixed_forms() {
        let a = parse(&["train", "--task", "listops", "--steps=100", "--verbose", "--lr", "1e-4"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get("task"), Some("listops"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 100);
        assert!(a.get_bool("verbose"));
        assert!((a.get_f32("lr", 0.0).unwrap() - 1e-4).abs() < 1e-10);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_or("task", "listops"), "listops");
        assert_eq!(a.get_usize("steps", 7).unwrap(), 7);
        assert!(!a.get_bool("verbose"));
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse(&["--steps", "abc"]);
        assert!(a.get_usize("steps", 0).is_err());
    }

    #[test]
    fn list_flag() {
        let a = parse(&["--attn", "softmax, skyformer,performer"]);
        assert_eq!(
            a.get_list("attn").unwrap(),
            vec!["softmax", "skyformer", "performer"]
        );
    }
}
