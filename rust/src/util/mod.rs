//! Infrastructure substrates the offline environment forced us to build:
//! a JSON parser/writer ([`json`]), a splittable PRNG ([`rng`]), a tiny
//! CLI-argument helper ([`args`]), error plumbing ([`error`]), and a
//! micro-benchmark timer ([`bench`]) standing in for criterion.

pub mod args;
pub mod bench;
pub mod error;
pub mod json;
pub mod rng;
