//! Deterministic splittable PRNG for workload generation and seed sweeps.
//!
//! SplitMix64 core with a `split(label)` operation, so every table row in
//! the benchmark harness is reproducible from the CLI seed alone
//! (DESIGN.md §6).  Not cryptographic — statistical quality only.

/// Splittable SplitMix64 PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // avalanche the seed so small seeds don't correlate
        Rng {
            state: seed ^ 0x9E37_79B9_7F4A_7C15,
        }
    }

    /// Derive an independent stream labelled by `label` without advancing
    /// this stream.
    pub fn split(&self, label: u64) -> Rng {
        let mut mixed = self.state ^ label.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        mixed = splitmix(&mut mixed);
        Rng { state: mixed }
    }

    /// Derive a stream from a string label (stable across runs).
    pub fn split_str(&self, label: &str) -> Rng {
        let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV-1a
        for b in label.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        self.split(h)
    }

    pub fn next_u64(&mut self) -> u64 {
        splitmix(&mut self.state)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Lemire-style rejection-free for our (non-crypto) needs
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// k distinct indices from [0, n) (k <= n), uniform without replacement.
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // partial Fisher–Yates over a lazily materialised permutation
        let mut map = std::collections::HashMap::new();
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let j = i + self.below(n - i);
            let vi = *map.get(&i).unwrap_or(&i);
            let vj = *map.get(&j).unwrap_or(&j);
            map.insert(j, vi);
            out.push(vj);
        }
        out
    }

    /// Sample from a categorical distribution given cumulative weights.
    pub fn categorical(&mut self, cumulative: &[f32]) -> usize {
        let total = *cumulative.last().expect("empty categorical");
        let x = self.uniform() * total;
        match cumulative.binary_search_by(|w| w.partial_cmp(&x).unwrap()) {
            Ok(i) => (i + 1).min(cumulative.len() - 1),
            Err(i) => i.min(cumulative.len() - 1),
        }
    }
}

fn splitmix(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_independent_of_parent_advance() {
        let parent = Rng::new(7);
        let c1 = parent.split(1);
        let mut parent2 = parent.clone();
        parent2.next_u64();
        // split derives from state snapshot, not consumption order
        let c1b = parent.split(1);
        let mut x = c1.clone();
        let mut y = c1b.clone();
        assert_eq!(x.next_u64(), y.next_u64());
        let mut c2 = parent.split(2);
        assert_ne!(x.next_u64(), c2.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let mut sum = 0.0f64;
        let mut sq = 0.0f64;
        for _ in 0..n {
            let x = r.normal() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn choose_distinct_is_distinct_and_in_range() {
        let mut r = Rng::new(5);
        for _ in 0..50 {
            let v = r.choose_distinct(100, 30);
            let set: std::collections::HashSet<_> = v.iter().collect();
            assert_eq!(set.len(), 30);
            assert!(v.iter().all(|&x| x < 100));
        }
    }

    #[test]
    fn choose_distinct_full_is_permutation() {
        let mut r = Rng::new(9);
        let mut v = r.choose_distinct(20, 20);
        v.sort_unstable();
        assert_eq!(v, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(1);
        let mut seen0 = false;
        let mut seen_max = false;
        for _ in 0..10_000 {
            let x = r.below(7);
            assert!(x < 7);
            seen0 |= x == 0;
            seen_max |= x == 6;
        }
        assert!(seen0 && seen_max);
    }
}
