//! The one tiling implementation every dense kernel shares.
//!
//! All matmul-shaped loops in the crate — `Matrix::matmul`, the fused
//! score kernels, the softmax·V epilogue — reduce over `k` in strictly
//! increasing order, blocked in [`TILE_K`]-wide panels for cache reuse.
//! Blocking never reorders the reduction (a k-panel is a contiguous,
//! in-order slice of it), so the tiled result is bit-identical to a
//! naive `for k in 0..k` accumulation.  That single invariant is what
//! makes the scalar path, the 1-thread kernel path, and the N-thread
//! kernel path produce the same bytes.

/// Reduction panel width (f32 elements). 64 keeps a `TILE_K x n` panel
/// of the B operand inside L1/L2 for the Figure-1 sizes (n <= 1024).
pub const TILE_K: usize = 64;

/// `out_row[j] += sum_{kx in kk..k_end} a_row[kx] * b[kx * n + j]`
/// for every `j` — one output row, one k-panel, unit stride on both
/// operands (ikj order).
#[inline]
pub fn matmul_row_panel(
    out_row: &mut [f32],
    a_row: &[f32],
    b: &[f32],
    n: usize,
    kk: usize,
    k_end: usize,
) {
    for kx in kk..k_end {
        let a = a_row[kx];
        let b_row = &b[kx * n..kx * n + n];
        for (o, &bv) in out_row.iter_mut().zip(b_row) {
            *o += a * bv;
        }
    }
}

/// Accumulate one output row against the whole of `b` (`k x n`,
/// row-major), panel by panel: the remainder panel goes through the same
/// code path as full panels (`k_end` just stops short).
#[inline]
pub fn matmul_row(out_row: &mut [f32], a_row: &[f32], b: &[f32], n: usize, k: usize) {
    let mut kk = 0;
    while kk < k {
        let k_end = (kk + TILE_K).min(k);
        matmul_row_panel(out_row, a_row, b, n, kk, k_end);
        kk = k_end;
    }
}

/// Dot product reduced in increasing index order — the `matmul_transb` /
/// score-kernel inner loop, same reduction order as [`matmul_row_panel`].
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// Half squared norm `0.5 * ||x||^2` — the Gaussian-kernel row statistic.
#[inline]
pub fn half_sq_norm(x: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for v in x {
        acc += v * v;
    }
    0.5 * acc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_row(a_row: &[f32], b: &[f32], n: usize, k: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n];
        for (j, o) in out.iter_mut().enumerate() {
            for (kx, &av) in a_row.iter().enumerate().take(k) {
                *o += av * b[kx * n + j];
            }
        }
        out
    }

    #[test]
    fn panel_loop_is_bit_identical_to_naive_order() {
        // sizes straddling the panel boundary, including the remainder path
        for &k in &[1usize, TILE_K - 1, TILE_K, TILE_K + 1, 3 * TILE_K + 7] {
            let n = 5;
            let a_row: Vec<f32> = (0..k).map(|i| (i as f32 * 0.37).sin()).collect();
            let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.11).cos()).collect();
            let mut out = vec![0.0f32; n];
            matmul_row(&mut out, &a_row, &b, n, k);
            let want = naive_row(&a_row, &b, n, k);
            for j in 0..n {
                assert_eq!(out[j].to_bits(), want[j].to_bits(), "k={k} j={j}");
            }
        }
    }

    #[test]
    fn dot_matches_panel_reduction_order() {
        let k = TILE_K + 3;
        let a: Vec<f32> = (0..k).map(|i| (i as f32 * 0.23).sin()).collect();
        let b: Vec<f32> = (0..k).map(|i| (i as f32 * 0.31).cos()).collect();
        // dot against a 1-column B must equal matmul_row on the same data
        let mut out = [0.0f32];
        matmul_row(&mut out, &a, &b, 1, k);
        assert_eq!(dot(&a, &b).to_bits(), out[0].to_bits());
    }

    #[test]
    fn half_sq_norm_known_value() {
        assert_eq!(half_sq_norm(&[3.0, 4.0]), 12.5);
        assert_eq!(half_sq_norm(&[]), 0.0);
    }
}
