//! The one tiling implementation every dense kernel shares.
//!
//! All matmul-shaped loops in the crate — `Matrix::matmul`, the fused
//! score kernels, the softmax·V epilogue — reduce over `k` in a fixed
//! order, blocked in [`TILE_K`]-wide panels for cache reuse and
//! [`LANES`]-wide accumulator blocks for SIMD.  The fixed order is part
//! of the determinism contract (KERNELS.md):
//!
//! * [`matmul_row_panel`] keeps one accumulator per output element, so
//!   each element receives its `k` contributions in strictly increasing
//!   order — lane-blocking over *columns* never touches the reduction
//!   order, and the result is bit-identical to a naive `for k` loop.
//! * [`dot`] and [`half_sq_norm`] are genuine reductions, so widening
//!   them changes the summation order: each of the [`LANES`]
//!   accumulators reduces its stride-`LANES` subsequence in increasing
//!   index order, the lanes are combined in increasing-lane order, and
//!   the tail (`len % LANES`) is folded in last, in increasing index
//!   order.  That order is fixed — independent of thread count, pool
//!   mode, and panel boundaries — and `ops::reference` implements the
//!   same order, which keeps bit-exact parity a checkable contract.

/// Reduction panel width (f32 elements). 64 keeps a `TILE_K x n` panel
/// of the B operand inside L1/L2 for the Figure-1 sizes (n <= 1024).
pub const TILE_K: usize = 64;

/// SIMD accumulator block width (f32 elements).  8 matches one AVX2
/// register / one TPU VPU sublane and divides [`TILE_K`]; the explicit
/// `[f32; LANES]` blocks below keep accumulators in registers across a
/// whole k-panel instead of round-tripping through the output slice.
pub const LANES: usize = 8;

/// `out_row[j] += sum_{kx in kk..k_end} a_row[kx] * b[kx * n + j]`
/// for every `j` — one output row, one k-panel, unit stride on both
/// operands (ikj order).  Columns are processed in [`LANES`]-wide
/// accumulator blocks held across the whole panel; the per-element
/// reduction order (increasing `kx`) is unchanged by the blocking, so
/// outputs stay bit-identical to the naive loop.
#[inline]
pub fn matmul_row_panel(
    out_row: &mut [f32],
    a_row: &[f32],
    b: &[f32],
    n: usize,
    kk: usize,
    k_end: usize,
) {
    let mut j0 = 0;
    while j0 + LANES <= n {
        let mut acc = [0.0f32; LANES];
        acc.copy_from_slice(&out_row[j0..j0 + LANES]);
        for kx in kk..k_end {
            let a = a_row[kx];
            let b_blk = &b[kx * n + j0..kx * n + j0 + LANES];
            for (l, acc_l) in acc.iter_mut().enumerate() {
                *acc_l += a * b_blk[l];
            }
        }
        out_row[j0..j0 + LANES].copy_from_slice(&acc);
        j0 += LANES;
    }
    if j0 < n {
        // column tail: same per-element increasing-kx order, scalar width
        for kx in kk..k_end {
            let a = a_row[kx];
            let b_row = &b[kx * n..kx * n + n];
            for (o, &bv) in out_row[j0..].iter_mut().zip(&b_row[j0..]) {
                *o += a * bv;
            }
        }
    }
}

/// Accumulate one output row against the whole of `b` (`k x n`,
/// row-major), panel by panel: the remainder panel goes through the same
/// code path as full panels (`k_end` just stops short).
#[inline]
pub fn matmul_row(out_row: &mut [f32], a_row: &[f32], b: &[f32], n: usize, k: usize) {
    let mut kk = 0;
    while kk < k {
        let k_end = (kk + TILE_K).min(k);
        matmul_row_panel(out_row, a_row, b, n, kk, k_end);
        kk = k_end;
    }
}

/// Dot product in the fixed lane order — the `matmul_transb` /
/// score-kernel inner loop.  [`LANES`] accumulators sweep full blocks,
/// lanes combine in increasing-lane order, the tail folds in last.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let blocks = a.len() / LANES;
    let mut acc = [0.0f32; LANES];
    for c in 0..blocks {
        let ax = &a[c * LANES..(c + 1) * LANES];
        let bx = &b[c * LANES..(c + 1) * LANES];
        for (l, acc_l) in acc.iter_mut().enumerate() {
            *acc_l += ax[l] * bx[l];
        }
    }
    let mut total = 0.0f32;
    for acc_l in acc {
        total += acc_l;
    }
    for (x, y) in a[blocks * LANES..].iter().zip(&b[blocks * LANES..]) {
        total += x * y;
    }
    total
}

/// Half squared norm `0.5 * ||x||^2` — the Gaussian-kernel row
/// statistic, reduced in the same fixed lane order as [`dot`].
#[inline]
pub fn half_sq_norm(x: &[f32]) -> f32 {
    let blocks = x.len() / LANES;
    let mut acc = [0.0f32; LANES];
    for c in 0..blocks {
        let xb = &x[c * LANES..(c + 1) * LANES];
        for (l, acc_l) in acc.iter_mut().enumerate() {
            *acc_l += xb[l] * xb[l];
        }
    }
    let mut total = 0.0f32;
    for acc_l in acc {
        total += acc_l;
    }
    for v in &x[blocks * LANES..] {
        total += v * v;
    }
    0.5 * total
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_row(a_row: &[f32], b: &[f32], n: usize, k: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n];
        for (j, o) in out.iter_mut().enumerate() {
            for (kx, &av) in a_row.iter().enumerate().take(k) {
                *o += av * b[kx * n + j];
            }
        }
        out
    }

    /// The fixed lane order [`dot`] promises, written independently.
    fn lane_ordered_dot(a: &[f32], b: &[f32]) -> f32 {
        let blocks = a.len() / LANES;
        let mut lanes = [0.0f32; LANES];
        for (i, (&x, &y)) in a.iter().zip(b).enumerate().take(blocks * LANES) {
            lanes[i % LANES] += x * y;
        }
        let mut total = lanes.iter().copied().fold(0.0f32, |t, l| t + l);
        for (x, y) in a[blocks * LANES..].iter().zip(&b[blocks * LANES..]) {
            total += x * y;
        }
        total
    }

    fn seq(n: usize, f: f32) -> Vec<f32> {
        (0..n).map(|i| (i as f32 * f).sin()).collect()
    }

    #[test]
    fn panel_loop_is_bit_identical_to_naive_order() {
        // k sizes straddling the panel boundary, n sizes straddling the
        // lane boundary (the column tail path)
        for &k in &[1usize, TILE_K - 1, TILE_K, TILE_K + 1, 3 * TILE_K + 7] {
            for &n in &[1usize, LANES - 1, LANES, LANES + 1, 2 * LANES + 1] {
                let a_row = seq(k, 0.37);
                let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.11).cos()).collect();
                let mut out = vec![0.0f32; n];
                matmul_row(&mut out, &a_row, &b, n, k);
                let want = naive_row(&a_row, &b, n, k);
                for j in 0..n {
                    assert_eq!(out[j].to_bits(), want[j].to_bits(), "k={k} n={n} j={j}");
                }
            }
        }
    }

    #[test]
    fn dot_matches_fixed_lane_order_at_lane_boundaries() {
        for &k in &[
            0usize,
            1,
            LANES - 1,
            LANES,
            LANES + 1,
            2 * LANES + 1,
            TILE_K,
            TILE_K + 3,
        ] {
            let a = seq(k, 0.23);
            let b: Vec<f32> = (0..k).map(|i| (i as f32 * 0.31).cos()).collect();
            assert_eq!(
                dot(&a, &b).to_bits(),
                lane_ordered_dot(&a, &b).to_bits(),
                "k={k}"
            );
        }
    }

    #[test]
    fn half_sq_norm_matches_dot_halved_at_lane_boundaries() {
        // same lane order as dot(x, x), then the single 0.5 multiply
        for &k in &[1usize, LANES - 1, LANES, LANES + 1, 2 * LANES + 1] {
            let x = seq(k, 0.41);
            assert_eq!(
                half_sq_norm(&x).to_bits(),
                (0.5 * lane_ordered_dot(&x, &x)).to_bits(),
                "k={k}"
            );
        }
    }

    #[test]
    fn half_sq_norm_known_value() {
        assert_eq!(half_sq_norm(&[3.0, 4.0]), 12.5);
        assert_eq!(half_sq_norm(&[]), 0.0);
    }

    #[test]
    fn lanes_divides_tile() {
        // keeps full panels an exact number of lane blocks wide when a
        // kernel tiles its columns by TILE_K (the score kernels do)
        assert_eq!(TILE_K % LANES, 0);
    }
}
