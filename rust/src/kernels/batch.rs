//! Batched multi-head attention dispatch: many independent attention
//! problems — requests × heads — submitted to the worker pool as **one**
//! `run_rows` job.
//!
//! This closes the ROADMAP "batched multi-head dispatch through one pool
//! job" item and is what the serving subsystem ([`crate::serve`]) runs
//! each micro-batch through.  Per-request dispatch pays one pool
//! publication (and, for small sequences, falls below
//! [`crate::kernels::PAR_MIN_FLOPS`] and runs inline on one core);
//! batching concatenates the output rows of every head of every request
//! into a single row partition, so one wakeup covers the whole batch and
//! the combined flop count engages the full pool width.
//!
//! **Determinism contract** (KERNELS.md): each output row of each item
//! is computed with exactly the float operations, in exactly the order,
//! of the per-request kernel composition —
//!
//! * [`batched_softmax_attention`] row = the [`super::ops::matmul_transb`]
//!   score row (one [`tile::dot`] per key) followed by the
//!   [`super::ops::row_softmax_matmul`] epilogue;
//! * [`batched_kernelized_attention`] row = the
//!   [`super::ops::gaussian_scores`] row (dot tile + exp epilogue over
//!   precomputed half norms) followed by the [`super::ops::matmul`]
//!   k-panel accumulation ([`tile::matmul_row`]).
//!
//! A row's bytes therefore depend only on its own item's `(q, k, v)` —
//! never on which batch the item landed in, the batch size, the thread
//! count, or the pool mode.  Batched output is *bit-identical* to
//! per-request dispatch, which is what lets the serving layer micro-batch
//! by timing — and shard its dispatchers, and reorder by priority lane —
//! without giving up reproducibility (tests/serve.rs pins this under
//! threads {1, 4} × both pool backends; tests/serve_stress.rs under
//! concurrent mixed-priority load).
//!
//! Call-site discipline: these entry points submit ONE pool job each, so
//! the caller must serialize calls.  The serving subsystem guarantees
//! this by funnelling every gathered batch — from however many dispatcher
//! shards — through its single compute-submitter thread
//! ([`crate::serve::dispatch`]); sharding parallelizes gathering, never
//! pool submission.

use crate::kernels::{ops::observed, pool, tile, KernelCtx};
use crate::linalg::Matrix;

/// One attention problem (one head of one request): `q` is `(n, p)`,
/// `k` is `(m, p)`, `v` is `(m, dv)`.  Items in a batch must agree on
/// all four dimensions (the serving batcher buckets by them).
#[derive(Clone, Copy)]
pub struct AttnItem<'a> {
    pub q: &'a Matrix,
    pub k: &'a Matrix,
    pub v: &'a Matrix,
}

impl AttnItem<'_> {
    /// `(n, m, p, dv)` of this item, with internal consistency asserted.
    pub fn dims(&self) -> (usize, usize, usize, usize) {
        assert_eq!(
            self.q.cols, self.k.cols,
            "attn item: q is {}x{} but k is {}x{}",
            self.q.rows, self.q.cols, self.k.rows, self.k.cols
        );
        assert_eq!(
            self.k.rows, self.v.rows,
            "attn item: k has {} rows but v has {}",
            self.k.rows, self.v.rows
        );
        (self.q.rows, self.k.rows, self.q.cols, self.v.cols)
    }
}

/// Assert every item shares the leader's shape and return it.
fn batch_dims(items: &[AttnItem]) -> (usize, usize, usize, usize) {
    let dims = items[0].dims();
    for (idx, item) in items.iter().enumerate().skip(1) {
        assert_eq!(
            item.dims(),
            dims,
            "attn batch: item {idx} shape differs from item 0 (batch by bucket first)"
        );
    }
    dims
}

/// Split the flat batched output buffer back into one `(n, dv)` matrix
/// per item.
fn split_outputs(flat: Vec<f32>, items: usize, n: usize, dv: usize) -> Vec<Matrix> {
    debug_assert_eq!(flat.len(), items * n * dv);
    flat.chunks(n * dv)
        .map(|c| Matrix { rows: n, cols: dv, data: c.to_vec() })
        .collect()
}

/// Batched `softmax(q k^T) v` over `items`, one pool job for the whole
/// batch: output rows `[item * n, (item + 1) * n)` hold item `item`'s
/// attention output.  Bit-identical to
/// `row_softmax_matmul(ctx, &matmul_transb(ctx, q, k), v)` per item.
pub fn batched_softmax_attention(ctx: KernelCtx, items: &[AttnItem]) -> Vec<Matrix> {
    if items.is_empty() {
        return Vec::new();
    }
    let (n, m, p, dv) = batch_dims(items);
    let per_item = 2.0 * n as f64 * p as f64 * m as f64
        + n as f64 * m as f64 * (2.0 * dv as f64 + 4.0);
    let flops = items.len() as f64 * per_item;
    observed(
        "batched_softmax_attention",
        "kernel_batched_softmax_attention_seconds",
        "kernel_batched_softmax_attention_flops",
        flops,
        || {
            let rows = items.len() * n;
            let threads = ctx.threads_for(flops);
            let mut out = vec![0.0f32; rows * dv];
            pool::run_rows_in(ctx.mode, threads, rows, dv, &mut out, |first_row, chunk| {
                let mut s_row = vec![0.0f32; m];
                let mut w = vec![0.0f32; m];
                for (r, out_row) in chunk.chunks_mut(dv).enumerate() {
                    let g = first_row + r;
                    let item = &items[g / n];
                    let q_row = item.q.row(g % n);
                    // score row: matmul_transb's op order, one dot per key
                    for (j, s) in s_row.iter_mut().enumerate() {
                        *s = tile::dot(q_row, item.k.row(j));
                    }
                    // fused softmax · V: row_softmax_matmul's op order
                    let max = s_row.iter().fold(f32::NEG_INFINITY, |acc, &x| acc.max(x));
                    let mut sum = 0.0f32;
                    for (wl, &x) in w.iter_mut().zip(&s_row) {
                        *wl = (x - max).exp();
                        sum += *wl;
                    }
                    let inv = 1.0 / sum.max(1e-30);
                    for (lx, &wl) in w.iter().enumerate() {
                        let v_row = item.v.row(lx);
                        for (o, &vv) in out_row.iter_mut().zip(v_row) {
                            *o += wl * vv;
                        }
                    }
                    for o in out_row.iter_mut() {
                        *o *= inv;
                    }
                }
            });
            split_outputs(out, items.len(), n, dv)
        },
    )
}

/// Batched Kernelized Attention `exp(-||q_i - k_j||^2 / 2) v` (paper
/// Eq. 3) over `items`, one pool job for the whole batch.
/// Bit-identical to `matmul(ctx, &gaussian_scores(ctx, q, k), v)`
/// (= `exact::kernelized_attention`) per item.
pub fn batched_kernelized_attention(ctx: KernelCtx, items: &[AttnItem]) -> Vec<Matrix> {
    if items.is_empty() {
        return Vec::new();
    }
    let (n, m, p, dv) = batch_dims(items);
    let per_item = n as f64 * m as f64 * (2.0 * p as f64 + 3.0)
        + 2.0 * n as f64 * m as f64 * dv as f64;
    let flops = items.len() as f64 * per_item;
    observed(
        "batched_kernelized_attention",
        "kernel_batched_kernelized_attention_seconds",
        "kernel_batched_kernelized_attention_flops",
        flops,
        || {
            // per-item half norms once, exactly as gaussian_scores
            // precomputes them — the only non-output storage
            let nq: Vec<Vec<f32>> = items
                .iter()
                .map(|it| (0..n).map(|i| tile::half_sq_norm(it.q.row(i))).collect())
                .collect();
            let nk: Vec<Vec<f32>> = items
                .iter()
                .map(|it| (0..m).map(|j| tile::half_sq_norm(it.k.row(j))).collect())
                .collect();
            let rows = items.len() * n;
            let threads = ctx.threads_for(flops);
            let mut out = vec![0.0f32; rows * dv];
            pool::run_rows_in(ctx.mode, threads, rows, dv, &mut out, |first_row, chunk| {
                let mut g_row = vec![0.0f32; m];
                for (r, out_row) in chunk.chunks_mut(dv).enumerate() {
                    let g = first_row + r;
                    let (b, i) = (g / n, g % n);
                    let item = &items[b];
                    let q_row = item.q.row(i);
                    // gaussian score row: dot tile + exp epilogue, the
                    // gaussian_scores op order
                    let mut j0 = 0;
                    while j0 < m {
                        let j_end = (j0 + tile::TILE_K).min(m);
                        let mut dots = [0.0f32; tile::TILE_K];
                        for (t, j) in (j0..j_end).enumerate() {
                            dots[t] = tile::dot(q_row, item.k.row(j));
                        }
                        for (t, j) in (j0..j_end).enumerate() {
                            g_row[j] = (dots[t] - nq[b][i] - nk[b][j]).exp();
                        }
                        j0 = j_end;
                    }
                    // out_row = g_row @ V: matmul's k-panel order
                    tile::matmul_row(out_row, &g_row, &item.v.data, dv, m);
                }
            });
            split_outputs(out, items.len(), n, dv)
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{self, pool};
    use crate::util::rng::Rng;

    fn items_data(count: usize, n: usize, m: usize, p: usize, dv: usize) -> Vec<[Matrix; 3]> {
        let mut rng = Rng::new(17);
        (0..count)
            .map(|_| {
                [
                    Matrix::randn(&mut rng, n, p, 0.5),
                    Matrix::randn(&mut rng, m, p, 0.5),
                    Matrix::randn(&mut rng, m, dv, 1.0),
                ]
            })
            .collect()
    }

    fn as_items(data: &[[Matrix; 3]]) -> Vec<AttnItem<'_>> {
        data.iter().map(|[q, k, v]| AttnItem { q, k, v }).collect()
    }

    fn bits_equal(a: &Matrix, b: &Matrix) -> bool {
        a.rows == b.rows
            && a.cols == b.cols
            && a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn batched_softmax_matches_per_request_composition_bitwise() {
        let data = items_data(3, 13, 11, 8, 5);
        let items = as_items(&data);
        for mode in [pool::Mode::Scoped, pool::Mode::Pinned] {
            for threads in [1usize, 4] {
                let ctx = KernelCtx::with_threads(threads).with_mode(mode);
                let outs = batched_softmax_attention(ctx, &items);
                assert_eq!(outs.len(), 3);
                for (out, [q, k, v]) in outs.iter().zip(&data) {
                    let s = kernels::matmul_transb(ctx, q, k);
                    let want = kernels::row_softmax_matmul(ctx, &s, v);
                    assert!(bits_equal(out, &want), "{mode:?} x {threads} threads");
                }
            }
        }
    }

    #[test]
    fn batched_kernelized_matches_per_request_composition_bitwise() {
        let data = items_data(2, 9, 14, 8, 6);
        let items = as_items(&data);
        for mode in [pool::Mode::Scoped, pool::Mode::Pinned] {
            for threads in [1usize, 4] {
                let ctx = KernelCtx::with_threads(threads).with_mode(mode);
                let outs = batched_kernelized_attention(ctx, &items);
                for (out, [q, k, v]) in outs.iter().zip(&data) {
                    let want = kernels::matmul(ctx, &kernels::gaussian_scores(ctx, q, k), v);
                    assert!(bits_equal(out, &want), "{mode:?} x {threads} threads");
                }
            }
        }
    }

    #[test]
    fn output_is_independent_of_batch_composition() {
        // the serving-layer invariant: an item's bytes don't change when
        // its batch peers do — a request digests the same whether it was
        // coalesced with 0, 2, or 5 neighbours
        let data = items_data(6, 10, 10, 8, 8);
        let items = as_items(&data);
        let ctx = KernelCtx::with_threads(4);
        let all = batched_softmax_attention(ctx, &items);
        let solo = batched_softmax_attention(ctx, &items[2..3]);
        assert!(bits_equal(&all[2], &solo[0]));
        let pair = batched_softmax_attention(ctx, &items[1..3]);
        assert!(bits_equal(&all[2], &pair[1]));
    }

    #[test]
    fn empty_batch_is_noop() {
        let ctx = KernelCtx::with_threads(4);
        assert!(batched_softmax_attention(ctx, &[]).is_empty());
        assert!(batched_kernelized_attention(ctx, &[]).is_empty());
    }

    #[test]
    #[should_panic(expected = "shape differs")]
    fn mixed_shapes_panic() {
        let a = items_data(1, 8, 8, 4, 4);
        let b = items_data(1, 9, 8, 4, 4);
        let items = vec![
            AttnItem { q: &a[0][0], k: &a[0][1], v: &a[0][2] },
            AttnItem { q: &b[0][0], k: &b[0][1], v: &b[0][2] },
        ];
        batched_softmax_attention(KernelCtx::with_threads(1), &items);
    }
}
