//! Native pallas-style kernel subsystem: the tiled parallel compute
//! layer every dense hot path runs on.
//!
//! Three pieces, zero external dependencies:
//!
//! * [`pool`] — scoped thread pool (`std::thread::scope`) with
//!   deterministic row-partitioned scheduling.
//! * [`tile`] — the single tiling implementation (k-panel reduction in
//!   strictly increasing order) shared by every matmul-shaped loop.
//! * [`ops`] — the kernels: [`ops::matmul`], [`ops::matmul_transb`],
//!   fused [`ops::gaussian_scores`] / [`ops::softmax_scores`], fused
//!   [`ops::row_softmax_matmul`], and the [`ops::scale_add`] epilogue.
//!
//! Routing: `linalg::Matrix::matmul`, the exact-attention paths, the
//! Figure-1 approximators, and the Nyström PSD-completion assembly all
//! dispatch through a [`KernelCtx`], which also records per-kernel obs
//! spans and `kernel_<name>_seconds` / `kernel_<name>_flops` log2
//! histograms (see OBSERVABILITY.md).
//!
//! **Determinism contract** (KERNELS.md): output rows are partitioned
//! contiguously by `(rows, threads)` alone, each row is written by
//! exactly one thread, and every reduction runs in increasing-k order —
//! so results are *bit-identical* for every thread count, and identical
//! to the naive scalar oracles in [`ops::reference`].  `scripts/ci.sh`
//! enforces this by diffing `skyformer kernels --digest` output across
//! thread counts and running the test suite under
//! `SKYFORMER_THREADS=1` and `=4`.
//!
//! Knobs: `SKYFORMER_THREADS=N` (env) and `--threads N` (CLI, wins)
//! pick the pool width; the default is `available_parallelism`.  Jobs
//! below [`PAR_MIN_FLOPS`] nominal flops run inline on the caller.

pub mod ops;
pub mod pool;
pub mod tile;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

use crate::linalg::Matrix;

pub use ops::{gaussian_scores, matmul, matmul_transb, row_softmax_matmul, scale_add, softmax_scores};

/// Below this nominal flop count a kernel runs inline on the caller
/// thread — spawning scoped threads costs more than the work saves.
pub const PAR_MIN_FLOPS: f64 = 4e6;

/// Dispatch context for the kernel layer: how wide the pool is.
///
/// [`KernelCtx::global`] reads the process-wide setting (`--threads` >
/// `SKYFORMER_THREADS` > `available_parallelism`); tests and benches pin
/// an explicit width with [`KernelCtx::with_threads`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelCtx {
    pub threads: usize,
}

impl KernelCtx {
    /// The process-wide context (see [`current_threads`]).
    pub fn global() -> KernelCtx {
        KernelCtx { threads: current_threads() }
    }

    /// A context pinned to exactly `n` threads (clamped to >= 1).
    pub fn with_threads(n: usize) -> KernelCtx {
        KernelCtx { threads: n.max(1) }
    }

    /// Threads actually used for a job of `flops` nominal work — 1 for
    /// jobs below [`PAR_MIN_FLOPS`], the pool width otherwise.
    pub fn threads_for(&self, flops: f64) -> usize {
        if flops < PAR_MIN_FLOPS {
            1
        } else {
            self.threads
        }
    }
}

static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

fn env_threads() -> usize {
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("SKYFORMER_THREADS")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
    })
}

/// The pool width [`KernelCtx::global`] resolves to right now:
/// the [`set_threads`] override if one was made, else `SKYFORMER_THREADS`
/// from the environment, else `available_parallelism`.
pub fn current_threads() -> usize {
    match THREAD_OVERRIDE.load(Ordering::Relaxed) {
        0 => env_threads(),
        n => n,
    }
}

/// Override the pool width process-wide (the `--threads` CLI knob).
/// Clamped to >= 1; takes effect for every subsequent kernel call.
pub fn set_threads(n: usize) {
    THREAD_OVERRIDE.store(n.max(1), Ordering::Relaxed);
}

/// Order-sensitive FNV-1a digest of a matrix's exact bit pattern — the
/// currency of the CI determinism check (`skyformer kernels --digest`):
/// two runs diverge in any bit of any kernel output iff digests differ.
pub fn digest(m: &Matrix) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    h = (h ^ m.rows as u64).wrapping_mul(0x0000_0100_0000_01b3);
    h = (h ^ m.cols as u64).wrapping_mul(0x0000_0100_0000_01b3);
    for x in &m.data {
        h = (h ^ x.to_bits() as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn with_threads_clamps_to_one() {
        assert_eq!(KernelCtx::with_threads(0).threads, 1);
        assert_eq!(KernelCtx::with_threads(6).threads, 6);
    }

    #[test]
    fn small_jobs_run_inline() {
        let ctx = KernelCtx::with_threads(8);
        assert_eq!(ctx.threads_for(10.0), 1);
        assert_eq!(ctx.threads_for(PAR_MIN_FLOPS), 8);
    }

    #[test]
    fn digest_is_stable_and_sensitive() {
        let mut rng = Rng::new(0);
        let a = Matrix::randn(&mut rng, 8, 8, 1.0);
        assert_eq!(digest(&a), digest(&a.clone()));
        let mut b = a.clone();
        b.data[17] += 1e-7;
        assert_ne!(digest(&a), digest(&b));
        // shape participates even when data is empty
        assert_ne!(digest(&Matrix::zeros(2, 3)), digest(&Matrix::zeros(3, 2)));
    }

    #[test]
    fn global_ctx_has_at_least_one_thread() {
        assert!(KernelCtx::global().threads >= 1);
    }
}
