//! Native pallas-style kernel subsystem: the tiled parallel compute
//! layer every dense hot path runs on.
//!
//! Three pieces, zero external dependencies:
//!
//! * [`pool`] — deterministic row-partitioned scheduling over two
//!   backends: a pinned persistent worker pool (parked between calls,
//!   the default) and a scoped-spawn fallback (`SKYFORMER_POOL`).
//! * [`tile`] — the single tiling implementation (k-panel blocking,
//!   [`tile::LANES`]-wide accumulator blocks, fixed reduction order)
//!   shared by every matmul-shaped loop.
//! * [`ops`] — the kernels: [`ops::matmul`], [`ops::matmul_transa`],
//!   [`ops::matmul_transb`], fused [`ops::gaussian_scores`] /
//!   [`ops::softmax_scores`], fused [`ops::row_softmax_matmul`], and
//!   the [`ops::scale_add`] epilogue.
//!
//! Routing: `linalg::Matrix::matmul`, the exact-attention paths, the
//! Figure-1 approximators, and the Nyström PSD-completion assembly all
//! dispatch through a [`KernelCtx`], which also records per-kernel obs
//! spans and `kernel_<name>_seconds` / `kernel_<name>_flops` log2
//! histograms (see OBSERVABILITY.md).
//!
//! **Determinism contract** (KERNELS.md): output rows are partitioned
//! contiguously by `(rows, threads)` alone, each row is written by
//! exactly one executor, and every reduction runs in a fixed order
//! (increasing-k per element; the [`tile::LANES`] lane order for
//! dot-shaped reductions) — so results are *bit-identical* for every
//! thread count **and both pool modes**, and identical to the naive
//! scalar oracles in [`ops::reference`].  `scripts/ci.sh` enforces this
//! by diffing `skyformer kernels --digest` output across thread counts
//! × pool modes against the committed golden fixture
//! (`rust/tests/golden/kernels.digest`) and running the test suite
//! under both modes.
//!
//! Knobs: `SKYFORMER_THREADS=N` (env) and `--threads N` (CLI, wins)
//! pick the pool width; the default is `available_parallelism`.
//! `SKYFORMER_POOL=scoped|pinned` (env) and `--pool` (CLI, wins) pick
//! the backend.  Jobs below [`PAR_MIN_FLOPS`] nominal flops run inline
//! on the caller.

pub mod batch;
pub mod ops;
pub mod pool;
pub mod tile;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

use crate::linalg::Matrix;
use crate::util::rng::Rng;

pub use batch::{batched_kernelized_attention, batched_softmax_attention, AttnItem};
pub use ops::{
    gaussian_scores, matmul, matmul_transa, matmul_transb, row_softmax_matmul, scale_add,
    softmax_scores,
};

/// Below this nominal flop count a kernel runs inline on the caller
/// thread — spawning scoped threads costs more than the work saves.
pub const PAR_MIN_FLOPS: f64 = 4e6;

/// Dispatch context for the kernel layer: how wide the pool is and
/// which backend runs it.
///
/// [`KernelCtx::global`] reads the process-wide settings (`--threads` >
/// `SKYFORMER_THREADS` > `available_parallelism`; `--pool` >
/// `SKYFORMER_POOL` > pinned); tests and benches pin an explicit width
/// with [`KernelCtx::with_threads`] and a backend with
/// [`KernelCtx::with_mode`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelCtx {
    pub threads: usize,
    pub mode: pool::Mode,
}

impl KernelCtx {
    /// The process-wide context (see [`current_threads`] and
    /// [`pool::current_mode`]).
    pub fn global() -> KernelCtx {
        KernelCtx { threads: current_threads(), mode: pool::current_mode() }
    }

    /// A context pinned to exactly `n` threads (clamped to >= 1), using
    /// the process-wide pool mode.
    pub fn with_threads(n: usize) -> KernelCtx {
        KernelCtx { threads: n.max(1), mode: pool::current_mode() }
    }

    /// The same context pinned to an explicit pool backend.
    pub fn with_mode(self, mode: pool::Mode) -> KernelCtx {
        KernelCtx { mode, ..self }
    }

    /// Threads actually used for a job of `flops` nominal work — 1 for
    /// jobs below [`PAR_MIN_FLOPS`], the pool width otherwise.
    pub fn threads_for(&self, flops: f64) -> usize {
        if flops < PAR_MIN_FLOPS {
            1
        } else {
            self.threads
        }
    }
}

static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

fn env_threads() -> usize {
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("SKYFORMER_THREADS")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
    })
}

/// The pool width [`KernelCtx::global`] resolves to right now:
/// the [`set_threads`] override if one was made, else `SKYFORMER_THREADS`
/// from the environment, else `available_parallelism`.
pub fn current_threads() -> usize {
    match THREAD_OVERRIDE.load(Ordering::Relaxed) {
        0 => env_threads(),
        n => n,
    }
}

/// Override the pool width process-wide (the `--threads` CLI knob).
/// Clamped to >= 1; takes effect for every subsequent kernel call.
pub fn set_threads(n: usize) {
    THREAD_OVERRIDE.store(n.max(1), Ordering::Relaxed);
}

/// Order-sensitive FNV-1a digest of a matrix's exact bit pattern — the
/// currency of the CI determinism check (`skyformer kernels --digest`):
/// two runs diverge in any bit of any kernel output iff digests differ.
pub fn digest(m: &Matrix) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    h = (h ^ m.rows as u64).wrapping_mul(0x0000_0100_0000_01b3);
    h = (h ^ m.cols as u64).wrapping_mul(0x0000_0100_0000_01b3);
    for x in &m.data {
        h = (h ^ x.to_bits() as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The fixed digest workload behind `skyformer kernels` and the golden
/// fixture `rust/tests/golden/kernels.digest`: every kernel run once on
/// seeded inputs, paired with its [`ops::reference`] oracle output.
///
/// CLI and integration tests share this factory so the fixture can
/// never drift from what the binary prints.
pub fn digest_suite(
    ctx: KernelCtx,
    n: usize,
    p: usize,
    seed: u64,
) -> Vec<(&'static str, Matrix, Matrix)> {
    let mut rng = Rng::new(seed);
    let a = Matrix::randn(&mut rng, n, n, 0.5);
    let b = Matrix::randn(&mut rng, n, n, 0.5);
    let q = Matrix::randn(&mut rng, n, p, 0.5);
    let k = Matrix::randn(&mut rng, n, p, 0.5);
    let v = Matrix::randn(&mut rng, n, p, 1.0);
    let s = ops::matmul_transb(ctx, &q, &k);

    use ops::reference;
    vec![
        ("matmul", ops::matmul(ctx, &a, &b), reference::matmul(&a, &b)),
        ("matmul_transa", ops::matmul_transa(ctx, &a, &b), reference::matmul_transa(&a, &b)),
        (
            "matmul_transb",
            ops::matmul_transb(ctx, &a, &b),
            reference::matmul_transb(&a, &b),
        ),
        (
            "gaussian_scores",
            ops::gaussian_scores(ctx, &q, &k),
            reference::gaussian_scores(&q, &k),
        ),
        (
            "softmax_scores",
            ops::softmax_scores(ctx, &q, &k),
            reference::softmax_scores(&q, &k),
        ),
        (
            "row_softmax_matmul",
            ops::row_softmax_matmul(ctx, &s, &v),
            reference::row_softmax_matmul(&s, &v),
        ),
        (
            "scale_add",
            ops::scale_add(ctx, &a, 7.0, &b, -1.0),
            reference::scale_add(&a, 7.0, &b, -1.0),
        ),
        {
            // batched multi-head dispatch: three heads through one pool
            // job; digest the vcat so the line covers every head
            let items = [
                batch::AttnItem { q: &q, k: &k, v: &v },
                batch::AttnItem { q: &k, k: &q, v: &v },
                batch::AttnItem { q: &v, k: &q, v: &k },
            ];
            let outs = batch::batched_softmax_attention(ctx, &items);
            let got = outs[0].vcat(&outs[1]).vcat(&outs[2]);
            let want_one = |q: &Matrix, k: &Matrix, v: &Matrix| {
                reference::row_softmax_matmul(&reference::matmul_transb(q, k), v)
            };
            let want = want_one(&q, &k, &v)
                .vcat(&want_one(&k, &q, &v))
                .vcat(&want_one(&v, &q, &k));
            ("batched_softmax_attention", got, want)
        },
        {
            let items = [
                batch::AttnItem { q: &q, k: &k, v: &v },
                batch::AttnItem { q: &k, k: &q, v: &v },
                batch::AttnItem { q: &v, k: &q, v: &k },
            ];
            let outs = batch::batched_kernelized_attention(ctx, &items);
            let got = outs[0].vcat(&outs[1]).vcat(&outs[2]);
            let want_one = |q: &Matrix, k: &Matrix, v: &Matrix| {
                reference::matmul(&reference::gaussian_scores(q, k), v)
            };
            let want = want_one(&q, &k, &v)
                .vcat(&want_one(&k, &q, &v))
                .vcat(&want_one(&v, &q, &k));
            ("batched_kernelized_attention", got, want)
        },
    ]
}

/// The **portable** digest workload: kernels whose arithmetic is pure
/// IEEE-754 f32 `+`/`*` on [`Matrix::rand_uniform`] inputs — no libm
/// (`exp`/`ln`/`cos`) anywhere on the data path, so the digests are
/// identical on every IEEE platform and the committed fixture
/// `rust/tests/golden/kernels.portable.digest` can be generated off-host
/// (see `scripts/seed_golden_portable.py`) and *hard*-enforced
/// everywhere.  The libm-dependent kernels stay in [`digest_suite`],
/// whose fixture is pinned per-platform.
pub fn digest_suite_portable(
    ctx: KernelCtx,
    n: usize,
    seed: u64,
) -> Vec<(&'static str, Matrix, Matrix)> {
    let mut rng = Rng::new(seed);
    let a = Matrix::rand_uniform(&mut rng, n, n, -1.0, 1.0);
    let b = Matrix::rand_uniform(&mut rng, n, n, -1.0, 1.0);

    use ops::reference;
    vec![
        ("matmul", ops::matmul(ctx, &a, &b), reference::matmul(&a, &b)),
        ("matmul_transa", ops::matmul_transa(ctx, &a, &b), reference::matmul_transa(&a, &b)),
        (
            "matmul_transb",
            ops::matmul_transb(ctx, &a, &b),
            reference::matmul_transb(&a, &b),
        ),
        (
            "scale_add",
            ops::scale_add(ctx, &a, 7.0, &b, -1.0),
            reference::scale_add(&a, 7.0, &b, -1.0),
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn with_threads_clamps_to_one() {
        assert_eq!(KernelCtx::with_threads(0).threads, 1);
        assert_eq!(KernelCtx::with_threads(6).threads, 6);
    }

    #[test]
    fn small_jobs_run_inline() {
        let ctx = KernelCtx::with_threads(8);
        assert_eq!(ctx.threads_for(10.0), 1);
        assert_eq!(ctx.threads_for(PAR_MIN_FLOPS), 8);
    }

    #[test]
    fn digest_is_stable_and_sensitive() {
        let mut rng = Rng::new(0);
        let a = Matrix::randn(&mut rng, 8, 8, 1.0);
        assert_eq!(digest(&a), digest(&a.clone()));
        let mut b = a.clone();
        b.data[17] += 1e-7;
        assert_ne!(digest(&a), digest(&b));
        // shape participates even when data is empty
        assert_ne!(digest(&Matrix::zeros(2, 3)), digest(&Matrix::zeros(3, 2)));
    }

    #[test]
    fn global_ctx_has_at_least_one_thread() {
        assert!(KernelCtx::global().threads >= 1);
    }

    #[test]
    fn digest_suite_matches_reference_in_both_modes() {
        // small shapes keep this fast; the CLI/golden fixture runs the
        // full n=96 suite
        let mut want: Option<Vec<(&'static str, u64)>> = None;
        for mode in [pool::Mode::Scoped, pool::Mode::Pinned] {
            for threads in [1usize, 4] {
                let ctx = KernelCtx::with_threads(threads).with_mode(mode);
                let suite = digest_suite(ctx, 24, 8, 7);
                let got: Vec<(&'static str, u64)> = suite
                    .iter()
                    .map(|(name, out, reference)| {
                        assert_eq!(
                            digest(out),
                            digest(reference),
                            "{name} diverged from its scalar oracle ({mode:?}, {threads} threads)"
                        );
                        (*name, digest(out))
                    })
                    .collect();
                match &want {
                    None => want = Some(got),
                    Some(w) => assert_eq!(w, &got, "{mode:?} x {threads} threads diverged"),
                }
            }
        }
    }
}
