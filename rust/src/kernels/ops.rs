//! Tiled parallel kernels over the dense [`Matrix`] substrate.
//!
//! Every kernel: (1) partitions output rows across the worker pool
//! ([`crate::kernels::pool`] — pinned or scoped, per the `KernelCtx`
//! mode), (2) reduces through the shared tile helpers
//! ([`crate::kernels::tile`]) so there is exactly one tiling
//! implementation in the crate, and (3) records an obs span plus
//! `kernel_<name>_seconds` / `kernel_<name>_flops` log2 histograms.
//!
//! The fused kernels never materialise an intermediate beyond their
//! output: [`gaussian_scores`] builds `exp(q_i . k_j - ||q_i||^2/2 -
//! ||k_j||^2/2)` from precomputed row norms and a dot-product tile
//! (the distance matrix is never formed), and [`row_softmax_matmul`]
//! folds the row-stochastic softmax of a score matrix directly into the
//! `· V` accumulation (the softmaxed matrix is never formed).
//!
//! [`reference`] carries independent naive implementations — the scalar
//! oracles the parity property-tests and benches compare against.

use crate::kernels::{pool, tile, KernelCtx};
use crate::linalg::Matrix;
use crate::obs;

/// Record span + duration/FLOP histograms around one kernel invocation.
/// Metric names are static so the hot path never formats strings.
/// `pub(crate)` so the batched kernels in [`crate::kernels::batch`]
/// report through the same channel.
pub(crate) fn observed<R>(
    name: &'static str,
    seconds_metric: &'static str,
    flops_metric: &'static str,
    flops: f64,
    f: impl FnOnce() -> R,
) -> R {
    let _span = obs::span("kernel", name);
    let t = std::time::Instant::now();
    let out = f();
    obs::observe(seconds_metric, t.elapsed().as_secs_f64());
    obs::observe(flops_metric, flops);
    out
}

/// `a @ b` — cache-blocked over k-panels, rows split across the pool.
pub fn matmul(ctx: KernelCtx, a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(
        a.cols, b.rows,
        "matmul shape mismatch: {}x{} @ {}x{}",
        a.rows, a.cols, b.rows, b.cols
    );
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let flops = 2.0 * m as f64 * k as f64 * n as f64;
    observed("matmul", "kernel_matmul_seconds", "kernel_matmul_flops", flops, || {
        let threads = ctx.threads_for(flops);
        let mut out = Matrix::zeros(m, n);
        pool::run_rows_in(ctx.mode, threads, m, n, &mut out.data, |first_row, chunk| {
            // k-panel outer, rows inner: the B panel stays hot across
            // this chunk's rows, same schedule as the serial path
            let mut kk = 0;
            while kk < k {
                let k_end = (kk + tile::TILE_K).min(k);
                for (r, out_row) in chunk.chunks_mut(n).enumerate() {
                    tile::matmul_row_panel(out_row, a.row(first_row + r), &b.data, n, kk, k_end);
                }
                kk = k_end;
            }
        });
        out
    })
}

/// `a @ b^T` without materialising the transpose — both operands are
/// walked with unit stride (row · row dot products).
pub fn matmul_transb(ctx: KernelCtx, a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(
        a.cols, b.cols,
        "matmul_transb shape mismatch: {}x{} @ ({}x{})^T",
        a.rows, a.cols, b.rows, b.cols
    );
    let (m, n) = (a.rows, b.rows);
    let flops = 2.0 * m as f64 * a.cols as f64 * n as f64;
    observed(
        "matmul_transb",
        "kernel_matmul_transb_seconds",
        "kernel_matmul_transb_flops",
        flops,
        || {
            let threads = ctx.threads_for(flops);
            let mut out = Matrix::zeros(m, n);
            pool::run_rows_in(ctx.mode, threads, m, n, &mut out.data, |first_row, chunk| {
                for (r, out_row) in chunk.chunks_mut(n).enumerate() {
                    let a_row = a.row(first_row + r);
                    for (j, o) in out_row.iter_mut().enumerate() {
                        *o = tile::dot(a_row, b.row(j));
                    }
                }
            });
            out
        },
    )
}

/// `a^T @ b` without materialising the transpose: output row `i` is the
/// reduction of A's *column* `i` against the rows of `b`.  Each output
/// row gathers its O(k) column into a per-chunk scratch and reduces
/// through the shared tile helpers, so the per-element order is one add
/// per `r` in increasing order — **bit-identical** to
/// `matmul(ctx, &a.transpose(), b)` with no (k x m) transposed copy.
pub fn matmul_transa(ctx: KernelCtx, a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(
        a.rows, b.rows,
        "matmul_transa shape mismatch: ({}x{})^T @ {}x{}",
        a.rows, a.cols, b.rows, b.cols
    );
    let (m, k, n) = (a.cols, a.rows, b.cols);
    let flops = 2.0 * m as f64 * k as f64 * n as f64;
    observed(
        "matmul_transa",
        "kernel_matmul_transa_seconds",
        "kernel_matmul_transa_flops",
        flops,
        || {
            let threads = ctx.threads_for(flops);
            let mut out = Matrix::zeros(m, n);
            pool::run_rows_in(ctx.mode, threads, m, n, &mut out.data, |first_row, chunk| {
                let mut col = vec![0.0f32; k];
                for (r, out_row) in chunk.chunks_mut(n).enumerate() {
                    let i = first_row + r;
                    for (rr, c) in col.iter_mut().enumerate() {
                        *c = a.data[rr * a.cols + i];
                    }
                    tile::matmul_row(out_row, &col, &b.data, n, k);
                }
            });
            out
        },
    )
}

/// Which exponential score the fused kernel assembles.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ScoreEpilogue {
    /// `exp(-||a_i - b_j||^2 / 2)` via `exp(dot - na_i - nb_j)`.
    Gaussian,
    /// `exp(a_i . b_j)` — the softmax (SM) kernel.
    Softmax,
}

fn scores(
    ctx: KernelCtx,
    a: &Matrix,
    b: &Matrix,
    epilogue: ScoreEpilogue,
    name: &'static str,
    seconds_metric: &'static str,
    flops_metric: &'static str,
) -> Matrix {
    assert_eq!(
        a.cols, b.cols,
        "{name} shape mismatch: {}x{} vs {}x{}",
        a.rows, a.cols, b.rows, b.cols
    );
    let (m, n, p) = (a.rows, b.rows, a.cols);
    let flops = m as f64 * n as f64 * (2.0 * p as f64 + 3.0);
    observed(name, seconds_metric, flops_metric, flops, || {
        // row norms once — O((m + n) p), the only non-output storage
        let (na, nb) = match epilogue {
            ScoreEpilogue::Gaussian => (
                (0..m).map(|i| tile::half_sq_norm(a.row(i))).collect::<Vec<f32>>(),
                (0..n).map(|j| tile::half_sq_norm(b.row(j))).collect::<Vec<f32>>(),
            ),
            ScoreEpilogue::Softmax => (Vec::new(), Vec::new()),
        };
        let threads = ctx.threads_for(flops);
        let mut out = Matrix::zeros(m, n);
        pool::run_rows_in(ctx.mode, threads, m, n, &mut out.data, |first_row, chunk| {
            for (r, out_row) in chunk.chunks_mut(n).enumerate() {
                let i = first_row + r;
                let a_row = a.row(i);
                // dot-product tile, then the exp epilogue over the tile —
                // the n x n dot/distance matrix is never materialised
                let mut j0 = 0;
                while j0 < n {
                    let j_end = (j0 + tile::TILE_K).min(n);
                    let mut dots = [0.0f32; tile::TILE_K];
                    for (t, j) in (j0..j_end).enumerate() {
                        dots[t] = tile::dot(a_row, b.row(j));
                    }
                    match epilogue {
                        ScoreEpilogue::Gaussian => {
                            for (t, j) in (j0..j_end).enumerate() {
                                out_row[j] = (dots[t] - na[i] - nb[j]).exp();
                            }
                        }
                        ScoreEpilogue::Softmax => {
                            for (t, j) in (j0..j_end).enumerate() {
                                out_row[j] = dots[t].exp();
                            }
                        }
                    }
                    j0 = j_end;
                }
            }
        });
        out
    })
}

/// Fused Gaussian-kernel score matrix `exp(-||a_i - b_j||^2 / 2)` on
/// pre-scaled inputs, assembled tile-by-tile from row norms and dot
/// products (paper Eq. 2; the L1 Pallas kernel's native twin).
pub fn gaussian_scores(ctx: KernelCtx, a: &Matrix, b: &Matrix) -> Matrix {
    scores(
        ctx,
        a,
        b,
        ScoreEpilogue::Gaussian,
        "gaussian_scores",
        "kernel_gaussian_scores_seconds",
        "kernel_gaussian_scores_flops",
    )
}

/// Fused softmax-kernel score matrix `exp(a_i . b_j)` (paper's SM kernel).
pub fn softmax_scores(ctx: KernelCtx, a: &Matrix, b: &Matrix) -> Matrix {
    scores(
        ctx,
        a,
        b,
        ScoreEpilogue::Softmax,
        "softmax_scores",
        "kernel_softmax_scores_seconds",
        "kernel_softmax_scores_flops",
    )
}

/// Fused `softmax(s) @ v` — row-stable softmax folded into the `· V`
/// accumulation; the row-stochastic matrix is never materialised (one
/// `s.cols`-long scratch row per pool chunk).
pub fn row_softmax_matmul(ctx: KernelCtx, s: &Matrix, v: &Matrix) -> Matrix {
    assert_eq!(
        s.cols, v.rows,
        "row_softmax_matmul shape mismatch: softmax({}x{}) @ {}x{}",
        s.rows, s.cols, v.rows, v.cols
    );
    let (m, l, dv) = (s.rows, s.cols, v.cols);
    let flops = m as f64 * l as f64 * (2.0 * dv as f64 + 4.0);
    observed(
        "row_softmax_matmul",
        "kernel_row_softmax_matmul_seconds",
        "kernel_row_softmax_matmul_flops",
        flops,
        || {
            let threads = ctx.threads_for(flops);
            let mut out = Matrix::zeros(m, dv);
            pool::run_rows_in(ctx.mode, threads, m, dv, &mut out.data, |first_row, chunk| {
                let mut w = vec![0.0f32; l];
                for (r, out_row) in chunk.chunks_mut(dv).enumerate() {
                    let s_row = s.row(first_row + r);
                    let max = s_row.iter().fold(f32::NEG_INFINITY, |acc, &x| acc.max(x));
                    let mut sum = 0.0f32;
                    for (wl, &x) in w.iter_mut().zip(s_row) {
                        *wl = (x - max).exp();
                        sum += *wl;
                    }
                    let inv = 1.0 / sum.max(1e-30);
                    for (lx, &wl) in w.iter().enumerate() {
                        let v_row = v.row(lx);
                        for (o, &vv) in out_row.iter_mut().zip(v_row) {
                            *o += wl * vv;
                        }
                    }
                    for o in out_row.iter_mut() {
                        *o *= inv;
                    }
                }
            });
            out
        },
    )
}

/// Elementwise epilogue `alpha * a + beta * b` (the Newton–Schulz
/// `cI - AZ` updates run through this instead of scale+sub pairs).
pub fn scale_add(ctx: KernelCtx, a: &Matrix, alpha: f32, b: &Matrix, beta: f32) -> Matrix {
    assert_eq!(
        (a.rows, a.cols),
        (b.rows, b.cols),
        "scale_add shape mismatch: {}x{} vs {}x{}",
        a.rows, a.cols, b.rows, b.cols
    );
    let (m, n) = (a.rows, a.cols);
    let flops = 3.0 * m as f64 * n as f64;
    observed("scale_add", "kernel_scale_add_seconds", "kernel_scale_add_flops", flops, || {
        let threads = ctx.threads_for(flops);
        let mut out = Matrix::zeros(m, n);
        pool::run_rows_in(ctx.mode, threads, m, n, &mut out.data, |first_row, chunk| {
            let base = first_row * n;
            for (t, o) in chunk.iter_mut().enumerate() {
                *o = alpha * a.data[base + t] + beta * b.data[base + t];
            }
        });
        out
    })
}

/// Independent naive implementations — the scalar oracles for the parity
/// property-tests and the scalar series in the benches.  Reductions run
/// in the contract's fixed order — increasing-k per output element for
/// the matmul family, the [`crate::kernels::tile::LANES`] lane order for
/// dot-shaped reductions — which is what makes bit-exact parity a
/// checkable contract rather than a tolerance.
pub mod reference {
    use crate::kernels::tile::LANES;
    use crate::linalg::Matrix;

    /// The contract's fixed lane order, written independently of
    /// `kernels::tile`: lane `l` accumulates indices congruent to `l`
    /// (mod [`LANES`]) over the full blocks, lanes combine in
    /// increasing-lane order, the tail folds in last.
    fn lane_dot(a: &[f32], b: &[f32]) -> f32 {
        let full = (a.len() / LANES) * LANES;
        let mut lanes = [0.0f32; LANES];
        for (i, (&x, &y)) in a.iter().zip(b).enumerate().take(full) {
            lanes[i % LANES] += x * y;
        }
        let mut total = 0.0f32;
        for l in lanes {
            total += l;
        }
        for (&x, &y) in a[full..].iter().zip(&b[full..]) {
            total += x * y;
        }
        total
    }

    pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!(a.cols, b.rows);
        let mut out = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0.0f32;
                for kx in 0..a.cols {
                    acc += a[(i, kx)] * b[(kx, j)];
                }
                out[(i, j)] = acc;
            }
        }
        out
    }

    pub fn matmul_transa(a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!(a.rows, b.rows);
        let mut out = Matrix::zeros(a.cols, b.cols);
        for i in 0..a.cols {
            for j in 0..b.cols {
                let mut acc = 0.0f32;
                for r in 0..a.rows {
                    acc += a[(r, i)] * b[(r, j)];
                }
                out[(i, j)] = acc;
            }
        }
        out
    }

    pub fn matmul_transb(a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!(a.cols, b.cols);
        let mut out = Matrix::zeros(a.rows, b.rows);
        for i in 0..a.rows {
            for j in 0..b.rows {
                out[(i, j)] = lane_dot(a.row(i), b.row(j));
            }
        }
        out
    }

    pub fn gaussian_scores(a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!(a.cols, b.cols);
        let half = |row: &[f32]| 0.5 * lane_dot(row, row);
        let na: Vec<f32> = (0..a.rows).map(|i| half(a.row(i))).collect();
        let nb: Vec<f32> = (0..b.rows).map(|j| half(b.row(j))).collect();
        let mut out = Matrix::zeros(a.rows, b.rows);
        for i in 0..a.rows {
            for j in 0..b.rows {
                let d = lane_dot(a.row(i), b.row(j));
                out[(i, j)] = (d - na[i] - nb[j]).exp();
            }
        }
        out
    }

    pub fn softmax_scores(a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!(a.cols, b.cols);
        let mut out = Matrix::zeros(a.rows, b.rows);
        for i in 0..a.rows {
            for j in 0..b.rows {
                out[(i, j)] = lane_dot(a.row(i), b.row(j)).exp();
            }
        }
        out
    }

    pub fn row_softmax_matmul(s: &Matrix, v: &Matrix) -> Matrix {
        assert_eq!(s.cols, v.rows);
        let mut out = Matrix::zeros(s.rows, v.cols);
        for i in 0..s.rows {
            let s_row = s.row(i);
            let max = s_row.iter().fold(f32::NEG_INFINITY, |acc, &x| acc.max(x));
            let mut w = vec![0.0f32; s.cols];
            let mut sum = 0.0f32;
            for (wl, &x) in w.iter_mut().zip(s_row) {
                *wl = (x - max).exp();
                sum += *wl;
            }
            let inv = 1.0 / sum.max(1e-30);
            for (lx, &wl) in w.iter().enumerate() {
                for j in 0..v.cols {
                    out[(i, j)] += wl * v[(lx, j)];
                }
            }
            for j in 0..v.cols {
                out[(i, j)] *= inv;
            }
        }
        out
    }

    pub fn scale_add(a: &Matrix, alpha: f32, b: &Matrix, beta: f32) -> Matrix {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        Matrix::from_fn(a.rows, a.cols, |i, j| alpha * a[(i, j)] + beta * b[(i, j)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn bits_equal(a: &Matrix, b: &Matrix) -> bool {
        a.rows == b.rows
            && a.cols == b.cols
            && a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn matmul_matches_reference_bitwise_across_threads_and_modes() {
        let mut rng = Rng::new(0);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (7, 65, 3), (64, 64, 64), (33, 129, 17)] {
            let a = Matrix::randn(&mut rng, m, k, 1.0);
            let b = Matrix::randn(&mut rng, k, n, 1.0);
            let want = reference::matmul(&a, &b);
            for mode in [pool::Mode::Scoped, pool::Mode::Pinned] {
                for threads in [1usize, 2, 5] {
                    let ctx = KernelCtx::with_threads(threads).with_mode(mode);
                    let got = matmul(ctx, &a, &b);
                    assert!(bits_equal(&want, &got), "{m}x{k}x{n} threads={threads} {mode:?}");
                }
            }
        }
    }

    #[test]
    fn matmul_transb_matches_plain_matmul_of_transpose() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(&mut rng, 13, 9, 1.0);
        let b = Matrix::randn(&mut rng, 11, 9, 1.0);
        let got = matmul_transb(KernelCtx::with_threads(3), &a, &b);
        let want = reference::matmul_transb(&a, &b);
        assert!(bits_equal(&want, &got));
        // and within rounding of the unfused composition
        let composed = reference::matmul(&a, &b.transpose());
        assert!(got.sub(&composed).max_abs() < 1e-4);
    }

    #[test]
    fn matmul_transa_matches_reference_bitwise_across_threads_and_modes() {
        let mut rng = Rng::new(7);
        for &(k, m, n) in &[(1usize, 1usize, 1usize), (65, 7, 9), (40, 70, 17)] {
            let a = Matrix::randn(&mut rng, k, m, 1.0); // (k, m): a^T is (m, k)
            let b = Matrix::randn(&mut rng, k, n, 1.0);
            let want = reference::matmul_transa(&a, &b);
            for mode in [pool::Mode::Scoped, pool::Mode::Pinned] {
                for threads in [1usize, 3] {
                    let ctx = KernelCtx::with_threads(threads).with_mode(mode);
                    let got = matmul_transa(ctx, &a, &b);
                    assert!(bits_equal(&want, &got), "({k}x{m})^T@{k}x{n} {threads}t {mode:?}");
                }
            }
        }
    }

    #[test]
    fn matmul_transa_is_bit_identical_to_matmul_of_materialised_transpose() {
        // the transpose-elimination contract: callers may swap
        // `matmul(&a.transpose(), b)` for `matmul_transa(&a, b)` without
        // moving a single output bit
        let mut rng = Rng::new(8);
        let a = Matrix::randn(&mut rng, 33, 21, 1.0);
        let b = Matrix::randn(&mut rng, 33, 14, 1.0);
        let ctx = KernelCtx::with_threads(4);
        let fused = matmul_transa(ctx, &a, &b);
        let composed = matmul(ctx, &a.transpose(), &b);
        assert!(bits_equal(&fused, &composed));
    }

    #[test]
    fn gaussian_scores_matches_reference_bitwise() {
        let mut rng = Rng::new(2);
        for &(m, n, p) in &[(1usize, 1usize, 4usize), (20, 31, 8), (65, 64, 16)] {
            let a = Matrix::randn(&mut rng, m, p, 0.5);
            let b = Matrix::randn(&mut rng, n, p, 0.5);
            let want = reference::gaussian_scores(&a, &b);
            for threads in [1usize, 4] {
                let got = gaussian_scores(KernelCtx::with_threads(threads), &a, &b);
                assert!(bits_equal(&want, &got), "{m}x{n}x{p} threads={threads}");
            }
        }
    }

    #[test]
    fn gaussian_scores_diag_is_one_and_in_unit_interval() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(&mut rng, 18, 6, 0.7);
        let c = gaussian_scores(KernelCtx::with_threads(2), &a, &a);
        for i in 0..18 {
            assert!((c[(i, i)] - 1.0).abs() < 1e-5);
            for j in 0..18 {
                assert!(c[(i, j)] > 0.0 && c[(i, j)] <= 1.0 + 1e-6);
            }
        }
    }

    #[test]
    fn softmax_scores_matches_reference_bitwise() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(&mut rng, 9, 5, 0.5);
        let b = Matrix::randn(&mut rng, 14, 5, 0.5);
        let want = reference::softmax_scores(&a, &b);
        let got = softmax_scores(KernelCtx::with_threads(3), &a, &b);
        assert!(bits_equal(&want, &got));
    }

    #[test]
    fn row_softmax_matmul_matches_reference_bitwise_and_composition() {
        let mut rng = Rng::new(5);
        let s = Matrix::randn(&mut rng, 23, 17, 1.0);
        let v = Matrix::randn(&mut rng, 17, 7, 1.0);
        let want = reference::row_softmax_matmul(&s, &v);
        for threads in [1usize, 4] {
            let got = row_softmax_matmul(KernelCtx::with_threads(threads), &s, &v);
            assert!(bits_equal(&want, &got), "threads={threads}");
        }
        // vs the unfused softmax-then-matmul composition: equal to rounding
        let composed = reference::matmul(&crate::attention::exact::row_softmax(&s), &v);
        let got = row_softmax_matmul(KernelCtx::with_threads(2), &s, &v);
        assert!(got.sub(&composed).max_abs() < 1e-5);
    }

    #[test]
    fn scale_add_matches_reference() {
        let mut rng = Rng::new(6);
        let a = Matrix::randn(&mut rng, 12, 5, 1.0);
        let b = Matrix::randn(&mut rng, 12, 5, 1.0);
        let got = scale_add(KernelCtx::with_threads(3), &a, 2.5, &b, -1.0);
        let want = reference::scale_add(&a, 2.5, &b, -1.0);
        assert!(bits_equal(&want, &got));
    }

    #[test]
    fn lane_boundary_widths_match_reference_bitwise() {
        // mirror of the TILE-boundary regression at the LANES boundary:
        // the accumulator-block column tail (matmul) and the dot lane
        // tail (matmul_transb) both straddle LANES here
        use crate::kernels::tile::LANES;
        let mut rng = Rng::new(10);
        for &w in &[LANES - 1, LANES, LANES + 1, 2 * LANES + 1] {
            let a = Matrix::randn(&mut rng, 9, 33, 1.0);
            let b = Matrix::randn(&mut rng, 33, w, 1.0);
            let got = matmul(KernelCtx::with_threads(2), &a, &b);
            assert!(bits_equal(&got, &reference::matmul(&a, &b)), "matmul output width {w}");
            let a = Matrix::randn(&mut rng, 9, w, 1.0);
            let b = Matrix::randn(&mut rng, 7, w, 1.0);
            let got = matmul_transb(KernelCtx::with_threads(2), &a, &b);
            assert!(
                bits_equal(&got, &reference::matmul_transb(&a, &b)),
                "matmul_transb reduction width {w}"
            );
        }
    }

    #[test]
    fn large_matmul_engages_both_pool_backends_bit_identically() {
        // 2 * 128^3 ≈ 4.19e6 flops clears PAR_MIN_FLOPS, so this runs
        // through the actual worker pools rather than the inline path
        let ctx = KernelCtx::with_threads(4);
        assert_eq!(ctx.threads_for(2.0 * 128.0f64.powi(3)), 4);
        let mut rng = Rng::new(9);
        let a = Matrix::randn(&mut rng, 128, 128, 1.0);
        let b = Matrix::randn(&mut rng, 128, 128, 1.0);
        let scoped = matmul(ctx.with_mode(pool::Mode::Scoped), &a, &b);
        let pinned = matmul(ctx.with_mode(pool::Mode::Pinned), &a, &b);
        assert!(bits_equal(&scoped, &pinned));
        assert!(bits_equal(&scoped, &reference::matmul(&a, &b)));
    }

    #[test]
    fn empty_shapes_do_not_panic() {
        let a = Matrix::zeros(0, 4);
        let b = Matrix::zeros(4, 3);
        let c = matmul(KernelCtx::with_threads(4), &a, &b);
        assert_eq!((c.rows, c.cols), (0, 3));
        let d = Matrix::zeros(5, 0);
        let e = matmul(KernelCtx::with_threads(2), &d, &Matrix::zeros(0, 2));
        assert_eq!((e.rows, e.cols), (5, 2));
        assert!(e.data.iter().all(|&x| x == 0.0));
    }
}
