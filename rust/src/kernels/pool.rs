//! Scoped thread pool with deterministic row-partitioned scheduling.
//!
//! Every parallel kernel splits its *output* rows into at most `threads`
//! contiguous chunks and hands each chunk to one scoped thread
//! (`std::thread::scope` — no worker daemons, no unsafe lifetime
//! erasure).  The partition depends only on `(rows, threads)`, never on
//! timing, and each output row is written by exactly one thread, so the
//! bytes produced are identical for every thread count (see KERNELS.md,
//! "Determinism contract").
//!
//! Spawning is cheap relative to the O(n^3)/O(n^2 p) work the kernels
//! ship per call; callers still skip the pool entirely below a work
//! threshold (see [`crate::kernels::ops`]).

/// Run `f` over the rows of `out` (a `rows * row_len` row-major buffer),
/// split into at most `threads` contiguous row chunks.
///
/// `f(first_row, chunk)` receives the global index of its first row and
/// the mutable slice holding rows `first_row .. first_row + chunk_rows`.
/// With `threads == 1` this is a plain inline call — the scalar path and
/// the parallel path are the same code.
pub fn run_rows<F>(threads: usize, rows: usize, row_len: usize, out: &mut [f32], f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(out.len(), rows * row_len);
    if rows == 0 || row_len == 0 {
        return;
    }
    let threads = threads.clamp(1, rows);
    if threads == 1 {
        f(0, out);
        return;
    }
    // ceil split: the first chunks carry one extra row when rows % threads != 0
    let rows_per = rows.div_ceil(threads);
    std::thread::scope(|s| {
        let f = &f;
        for (t, chunk) in out.chunks_mut(rows_per * row_len).enumerate() {
            s.spawn(move || f(t * rows_per, chunk));
        }
    });
}

/// The deterministic row partition [`run_rows`] uses, as `(first, len)`
/// pairs — exposed so tests and docs can state the schedule exactly.
pub fn partition(rows: usize, threads: usize) -> Vec<(usize, usize)> {
    if rows == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, rows);
    let rows_per = rows.div_ceil(threads);
    let mut out = Vec::new();
    let mut first = 0;
    while first < rows {
        let len = rows_per.min(rows - first);
        out.push((first, len));
        first += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_rows_exactly_once() {
        for rows in [1usize, 2, 3, 7, 63, 64, 65, 100] {
            for threads in [1usize, 2, 3, 4, 7, 16] {
                let parts = partition(rows, threads);
                assert!(parts.len() <= threads.min(rows), "{rows}/{threads}");
                let mut next = 0;
                for &(first, len) in &parts {
                    assert_eq!(first, next);
                    assert!(len > 0);
                    next += len;
                }
                assert_eq!(next, rows, "{rows}/{threads}");
            }
        }
    }

    #[test]
    fn run_rows_writes_every_row_with_its_global_index() {
        for threads in [1usize, 2, 3, 5] {
            let (rows, row_len) = (11usize, 4usize);
            let mut out = vec![0.0f32; rows * row_len];
            run_rows(threads, rows, row_len, &mut out, |first_row, chunk| {
                for (r, row) in chunk.chunks_mut(row_len).enumerate() {
                    for x in row.iter_mut() {
                        *x = (first_row + r) as f32;
                    }
                }
            });
            for i in 0..rows {
                for j in 0..row_len {
                    assert_eq!(out[i * row_len + j], i as f32, "threads={threads}");
                }
            }
        }
    }

    #[test]
    fn run_rows_empty_is_noop() {
        let mut out: Vec<f32> = Vec::new();
        run_rows(4, 0, 8, &mut out, |_, _| panic!("must not run"));
    }
}
