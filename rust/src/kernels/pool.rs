//! Deterministic row-partitioned thread pools: scoped and pinned.
//!
//! Every parallel kernel splits its *output* rows into at most `threads`
//! contiguous chunks; the partition depends only on `(rows, threads)`,
//! never on timing, and each output row is written by exactly one
//! executor, so the bytes produced are identical for every thread count
//! — and for either pool mode (see KERNELS.md, "Determinism contract").
//!
//! Two execution backends ship behind the same [`run_rows`] API:
//!
//! * [`Mode::Scoped`] — `std::thread::scope` spawns fresh threads per
//!   call.  No daemons, no unsafe lifetime erasure; spawn cost is paid
//!   on every kernel invocation.
//! * [`Mode::Pinned`] — a lazily-initialised global set of persistent
//!   workers, parked on a condvar between calls and woken by a
//!   lightweight job publication.  Amortises spawn cost across the many
//!   small back-to-back kernel calls of the Newton–Schulz and Nyström
//!   block paths.  Workers *pull* chunk indices from a shared counter,
//!   so any number of live workers (including zero — the caller always
//!   participates) completes the same fixed partition.
//!
//! The mode comes from `SKYFORMER_POOL=scoped|pinned` (default: pinned)
//! or the process-wide [`set_mode`] override (`--pool` on the CLI);
//! kernels thread an explicit mode through `KernelCtx` so tests can pin
//! both backends side by side.  Pool health is observable through the
//! `pool_wakeups_total` counter and `pool_park_seconds` histogram
//! (see OBSERVABILITY.md).

use std::any::Any;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::Instant;

use crate::obs;

/// Safety cap on persistent workers — far above any sane `--threads`.
const MAX_WORKERS: usize = 256;

/// Which backend executes the row partition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Fresh `std::thread::scope` threads per call.
    Scoped,
    /// Persistent parked workers woken per job (the default).
    Pinned,
}

impl Mode {
    pub fn name(&self) -> &'static str {
        match self {
            Mode::Scoped => "scoped",
            Mode::Pinned => "pinned",
        }
    }

    pub fn parse(s: &str) -> Option<Mode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scoped" => Some(Mode::Scoped),
            "pinned" => Some(Mode::Pinned),
            _ => None,
        }
    }
}

// 0 = unset, 1 = scoped, 2 = pinned
static MODE_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

fn env_mode() -> Mode {
    static ENV: OnceLock<Mode> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("SKYFORMER_POOL")
            .ok()
            .and_then(|v| Mode::parse(&v))
            .unwrap_or(Mode::Pinned)
    })
}

/// The pool mode `KernelCtx::global()` resolves to right now: the
/// [`set_mode`] override if one was made, else `SKYFORMER_POOL` from the
/// environment, else [`Mode::Pinned`].
pub fn current_mode() -> Mode {
    match MODE_OVERRIDE.load(Ordering::Relaxed) {
        1 => Mode::Scoped,
        2 => Mode::Pinned,
        _ => env_mode(),
    }
}

/// Override the pool mode process-wide (the `--pool` CLI knob).
pub fn set_mode(mode: Mode) {
    let v = match mode {
        Mode::Scoped => 1,
        Mode::Pinned => 2,
    };
    MODE_OVERRIDE.store(v, Ordering::Relaxed);
}

/// Run `f` over the rows of `out` (a `rows * row_len` row-major buffer),
/// split into at most `threads` contiguous row chunks, on the
/// process-wide [`current_mode`] backend.
///
/// `f(first_row, chunk)` receives the global index of its first row and
/// the mutable slice holding rows `first_row .. first_row + chunk_rows`.
/// With `threads == 1` this is a plain inline call — the scalar path and
/// both parallel paths are the same code.
///
/// A panic in `f` propagates to the caller in both modes (pinned mode
/// cancels the job's remaining chunks, waits for every claimed chunk to
/// settle, then re-raises — workers and the pool stay usable).
pub fn run_rows<F>(threads: usize, rows: usize, row_len: usize, out: &mut [f32], f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    run_rows_in(current_mode(), threads, rows, row_len, out, f)
}

/// [`run_rows`] with an explicit backend — what `KernelCtx` dispatches
/// through, and what the parity tests use to pin both modes at once.
pub fn run_rows_in<F>(
    mode: Mode,
    threads: usize,
    rows: usize,
    row_len: usize,
    out: &mut [f32],
    f: F,
) where
    F: Fn(usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(out.len(), rows * row_len);
    if rows == 0 || row_len == 0 {
        return;
    }
    let threads = threads.clamp(1, rows);
    if threads == 1 {
        f(0, out);
        return;
    }
    match mode {
        Mode::Scoped => run_rows_scoped(threads, rows, row_len, out, f),
        Mode::Pinned => run_rows_pinned(threads, rows, row_len, out, f),
    }
}

fn run_rows_scoped<F>(threads: usize, rows: usize, row_len: usize, out: &mut [f32], f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    // ceil split: the first chunks carry one extra row when rows % threads != 0
    let rows_per = rows.div_ceil(threads);
    std::thread::scope(|s| {
        let f = &f;
        for (t, chunk) in out.chunks_mut(rows_per * row_len).enumerate() {
            s.spawn(move || f(t * rows_per, chunk));
        }
    });
}

/// The deterministic row partition [`run_rows`] uses, as `(first, len)`
/// pairs — exposed so tests and docs can state the schedule exactly.
/// Both pool modes execute exactly these chunks.
pub fn partition(rows: usize, threads: usize) -> Vec<(usize, usize)> {
    if rows == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, rows);
    let rows_per = rows.div_ceil(threads);
    let mut out = Vec::new();
    let mut first = 0;
    while first < rows {
        let len = rows_per.min(rows - first);
        out.push((first, len));
        first += len;
    }
    out
}

// --------------------------------------------------------- pinned pool

/// One published job: a type-erased chunk runner plus the shared chunk
/// claim counter.  Executors (workers and the submitting caller) pull
/// chunk indices from `next` until exhausted; which executor runs which
/// chunk never affects the output, because a chunk's bytes are a pure
/// function of `(chunk index, inputs)`.
struct JobInner {
    /// Runs chunk `t` of the job behind `ctx`.
    run: unsafe fn(*const (), usize),
    /// Points at a `CallCtx<F>` on the submitting caller's stack.  Valid
    /// until every chunk has completed — the caller blocks until then —
    /// and never dereferenced for claim indices `>= n_chunks`.
    ctx: *const (),
    n_chunks: usize,
    next: AtomicUsize,
    /// Set when any chunk's closure panicked: chunks claimed afterwards
    /// are counted as done without running, so completion (and therefore
    /// the caller's wait) still terminates.
    cancelled: AtomicBool,
    /// First panic payload caught by any executor; the submitting caller
    /// re-raises it after the completion wait.
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

// SAFETY: `ctx` is only dereferenced by executors holding a claimed
// chunk index < n_chunks, which the submitting caller outlives by
// construction: every executor (worker or caller) runs the closure
// under `catch_unwind`, so no unwind can skip the chunk-done
// accounting, and the caller waits for `chunks_done == n_chunks`
// before its stack frame is invalidated — even when re-raising a
// caught panic.  The closure behind `ctx` is `Sync`.
unsafe impl Send for JobInner {}
unsafe impl Sync for JobInner {}

struct PoolState {
    /// Bumped once per published job; workers use it to detect new work.
    epoch: u64,
    /// The job for the current epoch (cleared after completion).
    job: Option<Arc<JobInner>>,
    /// Chunks of the current job that have finished executing.
    chunks_done: usize,
    /// Persistent workers spawned so far.
    workers: usize,
}

struct Shared {
    state: Mutex<PoolState>,
    /// Workers park here between jobs.
    work: Condvar,
    /// The submitting caller parks here until `chunks_done == n_chunks`.
    done: Condvar,
}

struct PinnedPool {
    shared: Arc<Shared>,
    /// Serialises job submission: one job owns the workers at a time.
    /// Chunk granularity is coarse (≤ `threads` chunks per job), so the
    /// critical section is the job itself.  Corollary: a row closure
    /// must never submit a parallel kernel of its own (kernels call only
    /// `tile` helpers inside closures — nesting would self-deadlock
    /// here, where scoped mode would merely oversubscribe).
    submit: Mutex<()>,
}

/// Lock the pool state, shrugging off poison: the state mutex only
/// guards counter/epoch bookkeeping whose invariants hold at every
/// release point, and user-closure panics are caught before they can
/// unwind through a critical section anyway.
fn lock_state(shared: &Shared) -> MutexGuard<'_, PoolState> {
    shared.state.lock().unwrap_or_else(PoisonError::into_inner)
}

fn pinned_pool() -> &'static PinnedPool {
    static POOL: OnceLock<PinnedPool> = OnceLock::new();
    POOL.get_or_init(|| PinnedPool {
        shared: Arc::new(Shared {
            state: Mutex::new(PoolState {
                epoch: 0,
                job: None,
                chunks_done: 0,
                workers: 0,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        }),
        submit: Mutex::new(()),
    })
}

/// Body of one persistent worker: park until the epoch moves, clone the
/// published job, pull chunks until the counter runs dry, repeat.
///
/// If the thread ever exits (it shouldn't — chunk panics are caught in
/// [`run_claimed_chunks`]), a drop guard removes it from the worker
/// count so the next submission respawns a replacement instead of
/// silently running with a shrunken pool.
fn worker_loop(shared: Arc<Shared>) {
    struct DeregisterOnExit(Arc<Shared>);
    impl Drop for DeregisterOnExit {
        fn drop(&mut self) {
            lock_state(&self.0).workers -= 1;
        }
    }
    let _deregister = DeregisterOnExit(Arc::clone(&shared));

    let mut last_seen = {
        // never run a job published before this worker existed
        lock_state(&shared).epoch
    };
    loop {
        // Take only the job + park duration under the lock; the metrics
        // registry does its own locking, so recording there while `st`
        // is held would serialize every worker wakeup through it.
        let (job, parked) = {
            let mut st = lock_state(&shared);
            let parked_at = Instant::now();
            while st.epoch == last_seen {
                st = shared.work.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
            last_seen = st.epoch;
            (st.job.clone(), parked_at.elapsed())
        };
        obs::counter_add("pool_wakeups_total", 1);
        obs::observe("pool_park_seconds", parked.as_secs_f64());
        let Some(job) = job else { continue };
        run_claimed_chunks(&shared, &job);
    }
}

/// Pull chunk indices from `job.next` and execute them, reporting each
/// completion under the state lock (which also publishes the chunk's
/// writes to the waiting caller).
///
/// Panic-safe: the chunk closure runs under `catch_unwind`, so a panic
/// in user code can never skip the chunk-done accounting (which would
/// strand the caller on the `done` condvar while it holds the pool-wide
/// submit lock) or unwind a worker thread out of its loop.  On panic the
/// job is cancelled — chunks claimed afterwards are counted without
/// running — and the first payload is parked on the job for the
/// submitting caller to re-raise once every chunk has been accounted
/// for.
fn run_claimed_chunks(shared: &Shared, job: &JobInner) {
    loop {
        let t = job.next.fetch_add(1, Ordering::Relaxed);
        if t >= job.n_chunks {
            return;
        }
        if job.cancelled.load(Ordering::Acquire) {
            // an earlier chunk panicked: count this one as done without
            // running it so the caller's completion wait terminates
            finish_chunk(shared, job);
            continue;
        }
        // SAFETY: t < n_chunks, so the caller is still blocked in
        // run_rows_pinned and the CallCtx behind `ctx` is alive; chunk
        // t's output slice is disjoint from every other chunk's.
        // AssertUnwindSafe: on panic the job is cancelled and the
        // caller re-raises, so the partially-written output buffer is
        // only ever observed by unwinding code.
        let ran = std::panic::catch_unwind(AssertUnwindSafe(|| unsafe { (job.run)(job.ctx, t) }));
        if let Err(payload) = ran {
            job.cancelled.store(true, Ordering::Release);
            let mut slot = job.panic.lock().unwrap_or_else(PoisonError::into_inner);
            slot.get_or_insert(payload);
        }
        finish_chunk(shared, job);
    }
}

/// Report one chunk complete; the last chunk wakes the waiting caller.
fn finish_chunk(shared: &Shared, job: &JobInner) {
    let mut st = lock_state(shared);
    st.chunks_done += 1;
    if st.chunks_done == job.n_chunks {
        shared.done.notify_all();
    }
}

/// What the erased `run` pointer sees: everything needed to slice chunk
/// `t` out of the output buffer and call the row closure on it.
struct CallCtx<'a, F> {
    f: &'a F,
    out: *mut f32,
    rows: usize,
    row_len: usize,
    rows_per: usize,
}

unsafe fn run_chunk<F: Fn(usize, &mut [f32]) + Sync>(ctx: *const (), t: usize) {
    let c = unsafe { &*(ctx as *const CallCtx<F>) };
    let first = t * c.rows_per;
    let end = (first + c.rows_per).min(c.rows);
    // SAFETY: [first, end) rows form a disjoint, in-bounds slice of the
    // output buffer — exactly the chunk `chunks_mut` would hand out.
    let chunk = unsafe {
        std::slice::from_raw_parts_mut(c.out.add(first * c.row_len), (end - first) * c.row_len)
    };
    (c.f)(first, chunk);
}

fn run_rows_pinned<F>(threads: usize, rows: usize, row_len: usize, out: &mut [f32], f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let pool = pinned_pool();
    let rows_per = rows.div_ceil(threads);
    let n_chunks = rows.div_ceil(rows_per);
    let call = CallCtx {
        f: &f,
        out: out.as_mut_ptr(),
        rows,
        row_len,
        rows_per,
    };
    let job = Arc::new(JobInner {
        run: run_chunk::<F>,
        ctx: &call as *const CallCtx<F> as *const (),
        n_chunks,
        next: AtomicUsize::new(0),
        cancelled: AtomicBool::new(false),
        panic: Mutex::new(None),
    });

    // one job at a time owns the workers
    let _submit = pool.submit.lock().unwrap_or_else(PoisonError::into_inner);
    {
        let mut st = lock_state(&pool.shared);
        // grow the worker set to cover this width (workers are shared
        // across all widths; chunk-pulling tolerates any live count)
        let want = (threads - 1).min(MAX_WORKERS);
        while st.workers < want {
            let shared = Arc::clone(&pool.shared);
            let name = format!("skyformer-pool-{}", st.workers);
            match std::thread::Builder::new().name(name).spawn(|| worker_loop(shared)) {
                Ok(_) => st.workers += 1,
                Err(_) => break, // degrade gracefully: caller still completes the job
            }
        }
        st.epoch += 1;
        st.job = Some(Arc::clone(&job));
        st.chunks_done = 0;
        pool.shared.work.notify_all();
    }

    // The caller is an executor too — it claims chunks alongside the
    // workers.  run_claimed_chunks never unwinds (closure panics are
    // caught inside), so control always reaches the completion wait
    // below and `call`/`out`/`f` stay alive until no executor can still
    // dereference them.
    run_claimed_chunks(&pool.shared, &job);

    let mut st = lock_state(&pool.shared);
    while st.chunks_done < n_chunks {
        st = pool.shared.done.wait(st).unwrap_or_else(PoisonError::into_inner);
    }
    st.job = None; // drop the job (and its caller-stack pointer) with the epoch done
    drop(st);

    // Every chunk is accounted for and no executor holds `ctx` any
    // more; if any chunk's closure panicked, surface it here exactly as
    // the scoped backend would at scope exit.  Release the submit lock
    // first so the unwind does not poison it for the next job.
    let payload = job.panic.lock().unwrap_or_else(PoisonError::into_inner).take();
    if let Some(payload) = payload {
        drop(_submit);
        std::panic::resume_unwind(payload);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_rows_exactly_once() {
        for rows in [1usize, 2, 3, 7, 63, 64, 65, 100] {
            for threads in [1usize, 2, 3, 4, 7, 16] {
                let parts = partition(rows, threads);
                assert!(parts.len() <= threads.min(rows), "{rows}/{threads}");
                let mut next = 0;
                for &(first, len) in &parts {
                    assert_eq!(first, next);
                    assert!(len > 0);
                    next += len;
                }
                assert_eq!(next, rows, "{rows}/{threads}");
            }
        }
    }

    fn fill_rows(mode: Mode, threads: usize, rows: usize, row_len: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; rows * row_len];
        run_rows_in(mode, threads, rows, row_len, &mut out, |first_row, chunk| {
            for (r, row) in chunk.chunks_mut(row_len).enumerate() {
                for x in row.iter_mut() {
                    *x = (first_row + r) as f32;
                }
            }
        });
        out
    }

    #[test]
    fn run_rows_writes_every_row_with_its_global_index_in_both_modes() {
        for mode in [Mode::Scoped, Mode::Pinned] {
            for threads in [1usize, 2, 3, 5] {
                let (rows, row_len) = (11usize, 4usize);
                let out = fill_rows(mode, threads, rows, row_len);
                for i in 0..rows {
                    for j in 0..row_len {
                        assert_eq!(
                            out[i * row_len + j],
                            i as f32,
                            "mode={mode:?} threads={threads}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn pinned_matches_scoped_under_oversubscription() {
        // threads > rows must clamp to the same partition in both modes
        for (rows, threads) in [(3usize, 64usize), (1, 8), (5, 7), (16, 33)] {
            let scoped = fill_rows(Mode::Scoped, threads, rows, 3);
            let pinned = fill_rows(Mode::Pinned, threads, rows, 3);
            assert_eq!(scoped, pinned, "rows={rows} threads={threads}");
        }
    }

    #[test]
    fn pinned_survives_many_small_back_to_back_jobs() {
        // the Newton–Schulz shape: a tight loop of small jobs must not
        // wedge the parked workers or skip chunks
        for i in 0..200 {
            let rows = 2 + (i % 5);
            let out = fill_rows(Mode::Pinned, 4, rows, 2);
            for r in 0..rows {
                assert_eq!(out[r * 2], r as f32, "iteration {i}");
            }
        }
    }

    #[test]
    fn run_rows_empty_is_noop_in_both_modes() {
        for mode in [Mode::Scoped, Mode::Pinned] {
            let mut out: Vec<f32> = Vec::new();
            run_rows_in(mode, 4, 0, 8, &mut out, |_, _| panic!("must not run"));
        }
    }

    #[test]
    fn pinned_propagates_chunk_panic_and_pool_survives() {
        // A panicking row closure must (a) reach the caller as a panic,
        // exactly like scoped mode, (b) never strand the caller on the
        // completion wait, and (c) leave the pool usable — a wedged
        // submit lock or a silently-dead worker would hang or corrupt
        // every later job.
        for round in 0..3 {
            let caught = std::panic::catch_unwind(|| {
                let mut out = vec![0.0f32; 8 * 3];
                run_rows_in(Mode::Pinned, 4, 8, 3, &mut out, |first_row, _chunk| {
                    if first_row == 2 {
                        panic!("chunk panic (round {round})");
                    }
                });
            });
            assert!(caught.is_err(), "round {round}: panic was swallowed");
        }
        // pool still produces correct bytes after repeated panics
        let out = fill_rows(Mode::Pinned, 4, 11, 2);
        for r in 0..11 {
            assert_eq!(out[r * 2], r as f32, "post-panic job corrupted");
        }
    }

    #[test]
    fn pinned_panic_in_every_chunk_still_terminates() {
        // worst case: all claimed chunks panic; completion accounting
        // must still reach n_chunks and re-raise exactly one payload
        let caught = std::panic::catch_unwind(|| {
            let mut out = vec![0.0f32; 6 * 2];
            run_rows_in(Mode::Pinned, 3, 6, 2, &mut out, |_, _| panic!("all chunks"));
        });
        assert!(caught.is_err());
        let out = fill_rows(Mode::Pinned, 3, 6, 2);
        assert_eq!(out[10], 5.0);
    }

    #[test]
    fn mode_parse_roundtrip() {
        assert_eq!(Mode::parse("scoped"), Some(Mode::Scoped));
        assert_eq!(Mode::parse(" PINNED "), Some(Mode::Pinned));
        assert_eq!(Mode::parse("turbo"), None);
        assert_eq!(Mode::Pinned.name(), "pinned");
    }
}
