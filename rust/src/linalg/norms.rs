//! Spectral norm via power iteration on `A^T A` — the metric of the paper's
//! Definition 2 ((eps, delta)-MA) and the y-axis of Figure 1.

use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// Largest singular value of `a`, via power iteration on x -> A^T (A x).
///
/// Deterministic start vector + restart with a random vector if the first
/// converges to a null direction.  Relative accuracy ~1e-4 in <= `max_iter`.
pub fn spectral_norm(a: &Matrix) -> f32 {
    spectral_norm_iter(a, 300)
}

pub fn spectral_norm_iter(a: &Matrix, max_iter: usize) -> f32 {
    if a.rows == 0 || a.cols == 0 {
        return 0.0;
    }
    let mut rng = Rng::new(0x5EC7_0A17);
    let mut best = 0.0f32;
    for attempt in 0..2 {
        let mut x: Vec<f32> = if attempt == 0 {
            (0..a.cols).map(|i| 1.0 + (i as f32) * 1e-3).collect()
        } else {
            (0..a.cols).map(|_| rng.normal()).collect()
        };
        normalize(&mut x);
        let mut sigma_prev = 0.0f32;
        for _ in 0..max_iter {
            let y = a.matvec(&x);
            let mut z = a.matvec_t(&y);
            let nz = norm(&z);
            if nz == 0.0 {
                break;
            }
            for v in &mut z {
                *v /= nz;
            }
            x = z;
            let sigma = nz.sqrt();
            if (sigma - sigma_prev).abs() <= 1e-5 * sigma.max(1e-20) {
                sigma_prev = sigma;
                break;
            }
            sigma_prev = sigma;
        }
        best = best.max(sigma_prev);
        if best > 0.0 {
            break;
        }
    }
    best
}

fn norm(x: &[f32]) -> f32 {
    x.iter().map(|v| v * v).sum::<f32>().sqrt()
}

fn normalize(x: &mut [f32]) {
    let n = norm(x);
    if n > 0.0 {
        for v in x {
            *v /= n;
        }
    }
}

/// Relative spectral error ||A - B|| / ||A||, Figure 1's y-axis.
pub fn relative_spectral_error(a: &Matrix, b: &Matrix) -> f32 {
    let diff = a.sub(b);
    spectral_norm(&diff) / spectral_norm(a).max(1e-20)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagonal_matrix_norm() {
        let mut m = Matrix::zeros(4, 4);
        for (i, v) in [3.0f32, -7.0, 2.0, 0.5].iter().enumerate() {
            m[(i, i)] = *v;
        }
        assert!((spectral_norm(&m) - 7.0).abs() < 1e-3);
    }

    #[test]
    fn rank_one_norm() {
        // ||u v^T|| = ||u|| ||v||
        let u = [1.0f32, 2.0, 2.0]; // norm 3
        let v = [3.0f32, 4.0]; // norm 5
        let m = Matrix::from_fn(3, 2, |i, j| u[i] * v[j]);
        assert!((spectral_norm(&m) - 15.0).abs() < 1e-3);
    }

    #[test]
    fn orthogonal_matrix_norm_is_one() {
        let c = (0.3f32).cos();
        let s = (0.3f32).sin();
        let m = Matrix::from_rows(vec![vec![c, -s], vec![s, c]]);
        assert!((spectral_norm(&m) - 1.0).abs() < 1e-4);
    }

    #[test]
    fn zero_matrix() {
        assert_eq!(spectral_norm(&Matrix::zeros(3, 5)), 0.0);
    }

    #[test]
    fn relative_error_identity() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(&mut rng, 20, 20, 1.0);
        assert!(relative_spectral_error(&a, &a) < 1e-6);
    }
}
