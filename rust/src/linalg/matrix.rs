//! Row-major dense f32 matrix with the operations the approximation study
//! needs. The matmul dispatches through the pallas-style kernel subsystem
//! (`crate::kernels`): cache-blocked, ikj-ordered, row-parallel for large
//! jobs — enough to keep the Figure-1 sweep (n up to 1024) interactive
//! without BLAS.

use crate::util::rng::Rng;

#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    pub fn from_rows(rows: Vec<Vec<f32>>) -> Matrix {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        assert!(rows.iter().all(|x| x.len() == c), "ragged rows");
        Matrix {
            rows: r,
            cols: c,
            data: rows.into_iter().flatten().collect(),
        }
    }

    /// i.i.d. N(0, sigma^2) entries.
    pub fn randn(rng: &mut Rng, rows: usize, cols: usize, sigma: f32) -> Matrix {
        Matrix::from_fn(rows, cols, |_, _| rng.normal() * sigma)
    }

    /// i.i.d. Uniform[lo, hi) entries.  Unlike [`Matrix::randn`] (whose
    /// Box–Muller transform calls platform libm), this path is pure f32
    /// +/* arithmetic on 24-bit integers, so the values are reproducible
    /// bit-for-bit on any IEEE-754 platform — the portable golden digest
    /// suite depends on that (KERNELS.md, "Golden digest fixture").
    pub fn rand_uniform(rng: &mut Rng, rows: usize, cols: usize, lo: f32, hi: f32) -> Matrix {
        Matrix::from_fn(rows, cols, |_, _| rng.range_f32(lo, hi))
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Select a subset of rows.
    pub fn take_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.row(i));
        }
        out
    }

    /// Stack two matrices vertically.
    pub fn vcat(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols);
        let mut data = Vec::with_capacity((self.rows + other.rows) * self.cols);
        data.extend_from_slice(&self.data);
        data.extend_from_slice(&other.data);
        Matrix {
            rows: self.rows + other.rows,
            cols: self.cols,
            data,
        }
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// `self^T * s` in one pass — the Newton–Schulz seed shape.  Each
    /// element is the single product `self[(j, i)] * s`, so the result
    /// is bit-identical to `self.transpose().scale(s)` without the
    /// intermediate copy.
    pub fn transpose_scale(&self, s: f32) -> Matrix {
        Matrix::from_fn(self.cols, self.rows, |i, j| self[(j, i)] * s)
    }

    /// Matrix product through the kernel subsystem: cache-blocked over
    /// [`crate::kernels::tile::TILE_K`]-wide k-panels, ikj inner order
    /// (unit-stride on both operands), rows split across the scoped pool
    /// for large jobs.  The remainder panel goes through the same tile
    /// helper as full panels — there is one tiling implementation in the
    /// crate — and results are bit-identical for every thread count.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        crate::kernels::matmul(crate::kernels::KernelCtx::global(), self, other)
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a + b)
            .collect();
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data,
        }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data,
        }
    }

    pub fn scale(&self, s: f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|a| a * s).collect(),
        }
    }

    /// Add `s` to the diagonal (ridge).
    pub fn add_diag(&self, s: f32) -> Matrix {
        assert_eq!(self.rows, self.cols);
        let mut out = self.clone();
        for i in 0..self.rows {
            out[(i, i)] += s;
        }
        out
    }

    pub fn frobenius(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    /// y = self @ x for a vector x.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// y = self^T @ x.
    pub fn matvec_t(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.rows, x.len());
        let mut y = vec![0.0f32; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            for (yj, &a) in y.iter_mut().zip(self.row(i)) {
                *yj += xi * a;
            }
        }
        y
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f32;
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_known() {
        let a = Matrix::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Matrix::from_rows(vec![vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(0);
        let a = Matrix::randn(&mut rng, 17, 13, 1.0);
        let c = a.matmul(&Matrix::eye(13));
        assert_eq!(a, c);
    }

    #[test]
    fn matmul_matches_naive_blocked_boundaries() {
        let mut rng = Rng::new(1);
        // sizes straddling the 64 block boundary
        let a = Matrix::randn(&mut rng, 65, 130, 1.0);
        let b = Matrix::randn(&mut rng, 130, 67, 1.0);
        let c = a.matmul(&b);
        for &(i, j) in &[(0, 0), (64, 66), (30, 10)] {
            let want: f32 = (0..130).map(|k| a[(i, k)] * b[(k, j)]).sum();
            assert!((c[(i, j)] - want).abs() < 1e-3 * want.abs().max(1.0));
        }
    }

    #[test]
    fn matmul_tile_boundary_sizes_are_bit_exact_vs_naive() {
        // regression for the blocked-loop remainder path: every dimension
        // at 1, TILE-1, TILE, TILE+1 must match a naive increasing-k
        // accumulation bit-for-bit (the kernel determinism contract)
        use crate::kernels::tile::TILE_K;
        let naive = |a: &Matrix, b: &Matrix| -> Matrix {
            let mut out = Matrix::zeros(a.rows, b.cols);
            for i in 0..a.rows {
                for j in 0..b.cols {
                    let mut acc = 0.0f32;
                    for kx in 0..a.cols {
                        acc += a[(i, kx)] * b[(kx, j)];
                    }
                    out[(i, j)] = acc;
                }
            }
            out
        };
        use crate::kernels::tile::LANES;
        let sizes = [1usize, TILE_K - 1, TILE_K, TILE_K + 1];
        let mut rng = Rng::new(7);
        for &m in &sizes {
            for &k in &sizes {
                // n straddles both the lane boundary (accumulator-block
                // tail) and the panel boundary
                for &n in &[1usize, LANES - 1, LANES, LANES + 1, 2 * LANES + 1, TILE_K + 1] {
                    let a = Matrix::randn(&mut rng, m, k, 1.0);
                    let b = Matrix::randn(&mut rng, k, n, 1.0);
                    let got = a.matmul(&b);
                    let want = naive(&a, &b);
                    for (x, y) in got.data.iter().zip(&want.data) {
                        assert_eq!(x.to_bits(), y.to_bits(), "size {m}x{k}x{n}");
                    }
                }
            }
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(&mut rng, 5, 9, 1.0);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn transpose_scale_is_bit_identical_to_transpose_then_scale() {
        let mut rng = Rng::new(11);
        let a = Matrix::randn(&mut rng, 13, 7, 1.0);
        for &s in &[1.0f32, -0.25, 3.7e-3] {
            let fused = a.transpose_scale(s);
            let composed = a.transpose().scale(s);
            assert_eq!((fused.rows, fused.cols), (composed.rows, composed.cols));
            for (x, y) in fused.data.iter().zip(&composed.data) {
                assert_eq!(x.to_bits(), y.to_bits(), "s={s}");
            }
        }
    }

    #[test]
    fn matvec_agrees_with_matmul() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(&mut rng, 8, 6, 1.0);
        let x: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let y = a.matvec(&x);
        let xm = Matrix {
            rows: 6,
            cols: 1,
            data: x.clone(),
        };
        let ym = a.matmul(&xm);
        for i in 0..8 {
            assert!((y[i] - ym[(i, 0)]).abs() < 1e-5);
        }
    }

    #[test]
    fn take_rows_and_vcat() {
        let a = Matrix::from_rows(vec![vec![1.0], vec![2.0], vec![3.0]]);
        let b = a.take_rows(&[2, 0]);
        assert_eq!(b.data, vec![3.0, 1.0]);
        let c = a.vcat(&b);
        assert_eq!(c.rows, 5);
        assert_eq!(c.data, vec![1.0, 2.0, 3.0, 3.0, 1.0]);
    }
}
