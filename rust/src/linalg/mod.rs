//! Dense f32 linear algebra substrate.
//!
//! Built from scratch (no BLAS in the offline environment): a row-major
//! [`Matrix`] with a cache-blocked matmul, power-iteration spectral norm
//! ([`norms`]), one-sided Jacobi SVD ([`svd`]), and a Gauss–Jordan /
//! pseudo-inverse ([`solve`]).  Powers the Figure-1 approximation study,
//! the Figure-4 singular-value decay study, and the native Nyström module.

pub mod matrix;
pub mod norms;
pub mod solve;
pub mod svd;

pub use matrix::Matrix;
