//! Singular values via one-sided Jacobi — powers the Figure-4
//! singular-value-decay study on attention outputs (n x 64 matrices).
//!
//! One-sided Jacobi orthogonalises the columns of A by plane rotations;
//! the column norms of the converged matrix are the singular values.
//! O(cols^2 · rows) per sweep, fine for cols <= 128.

use crate::linalg::Matrix;

/// All singular values of `a`, descending. Converges to ~1e-5 relative.
pub fn singular_values(a: &Matrix) -> Vec<f32> {
    // work on the matrix with fewer columns
    let mut work = if a.rows < a.cols { a.transpose() } else { a.clone() };
    let n = work.cols;
    let max_sweeps = 30;
    for _ in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // gram entries of columns p, q
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..work.rows {
                    let xp = work[(i, p)] as f64;
                    let xq = work[(i, q)] as f64;
                    app += xp * xp;
                    aqq += xq * xq;
                    apq += xp * xq;
                }
                off += apq.abs();
                if apq.abs() <= 1e-12 * (app * aqq).sqrt().max(1e-30) {
                    continue;
                }
                // Jacobi rotation zeroing the (p,q) gram entry
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..work.rows {
                    let xp = work[(i, p)];
                    let xq = work[(i, q)];
                    work[(i, p)] = (c * xp as f64 - s * xq as f64) as f32;
                    work[(i, q)] = (s * xp as f64 + c * xq as f64) as f32;
                }
            }
        }
        if off < 1e-10 {
            break;
        }
    }
    let mut sv: Vec<f32> = (0..n)
        .map(|j| {
            (0..work.rows)
                .map(|i| (work[(i, j)] as f64).powi(2))
                .sum::<f64>()
                .sqrt() as f32
        })
        .collect();
    sv.sort_by(|a, b| b.partial_cmp(a).unwrap());
    sv
}

/// Condition number sigma_max / sigma_min (inf if singular).
pub fn condition_number(a: &Matrix) -> f32 {
    let sv = singular_values(a);
    let max = sv.first().copied().unwrap_or(0.0);
    let min = sv.last().copied().unwrap_or(0.0);
    if min <= 0.0 {
        f32::INFINITY
    } else {
        max / min
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn diagonal_singular_values() {
        let mut m = Matrix::zeros(4, 4);
        for (i, v) in [3.0f32, 7.0, 2.0, 0.5].iter().enumerate() {
            m[(i, i)] = *v;
        }
        let sv = singular_values(&m);
        let want = [7.0, 3.0, 2.0, 0.5];
        for (a, b) in sv.iter().zip(want) {
            assert!((a - b).abs() < 1e-4, "{sv:?}");
        }
    }

    #[test]
    fn matches_spectral_norm() {
        let mut rng = Rng::new(6);
        let a = Matrix::randn(&mut rng, 40, 12, 1.0);
        let sv = singular_values(&a);
        let sn = crate::linalg::norms::spectral_norm(&a);
        assert!((sv[0] - sn).abs() < 1e-2 * sn, "{} vs {}", sv[0], sn);
    }

    #[test]
    fn frobenius_identity() {
        // sum sigma_i^2 == ||A||_F^2
        let mut rng = Rng::new(7);
        let a = Matrix::randn(&mut rng, 25, 10, 1.0);
        let sv = singular_values(&a);
        let fro2: f32 = sv.iter().map(|s| s * s).sum();
        let want = a.frobenius().powi(2);
        assert!((fro2 - want).abs() < 1e-2 * want);
    }

    #[test]
    fn rank_deficient() {
        // rank-1 matrix: one nonzero singular value
        let u = [1.0f32, -2.0, 0.5];
        let v = [2.0f32, 1.0];
        let m = Matrix::from_fn(3, 2, |i, j| u[i] * v[j]);
        let sv = singular_values(&m);
        assert!(sv[1] < 1e-4 * sv[0], "{sv:?}");
    }

    #[test]
    fn wide_matrix_transposed_internally() {
        let mut rng = Rng::new(8);
        let a = Matrix::randn(&mut rng, 6, 50, 1.0);
        let sv_a = singular_values(&a);
        let sv_t = singular_values(&a.transpose());
        for (x, y) in sv_a.iter().zip(&sv_t) {
            assert!((x - y).abs() < 1e-3 * x.max(1.0));
        }
    }
}
