//! Exact and iterative inverses on the dense substrate.
//!
//! * [`gauss_jordan_inverse`] — partial-pivot exact inverse (the "CPU
//!   division-based" method of the paper's §4.4 discussion; used as the
//!   oracle the Newton–Schulz iteration is judged against).
//! * [`ns_inverse`] — the paper's preconditioned Newton–Schulz: the native
//!   twin of the L1 Pallas kernel, used by the Figure-1 study.

use crate::linalg::Matrix;
use crate::obs;
use crate::util::json;

/// Exact inverse by Gauss–Jordan with partial pivoting. Returns `None` if
/// the matrix is numerically singular.
pub fn gauss_jordan_inverse(m: &Matrix) -> Option<Matrix> {
    assert_eq!(m.rows, m.cols);
    let n = m.rows;
    let mut a = m.clone();
    let mut inv = Matrix::eye(n);
    for col in 0..n {
        // pivot
        let mut piv = col;
        let mut best = a[(col, col)].abs();
        for r in col + 1..n {
            if a[(r, col)].abs() > best {
                best = a[(r, col)].abs();
                piv = r;
            }
        }
        if best < 1e-12 {
            return None;
        }
        if piv != col {
            for j in 0..n {
                let t = a[(col, j)];
                a[(col, j)] = a[(piv, j)];
                a[(piv, j)] = t;
                let t = inv[(col, j)];
                inv[(col, j)] = inv[(piv, j)];
                inv[(piv, j)] = t;
            }
        }
        let d = a[(col, col)];
        for j in 0..n {
            a[(col, j)] /= d;
            inv[(col, j)] /= d;
        }
        for r in 0..n {
            if r == col {
                continue;
            }
            let f = a[(r, col)];
            if f == 0.0 {
                continue;
            }
            for j in 0..n {
                a[(r, j)] -= f * a[(col, j)];
                inv[(r, j)] -= f * inv[(col, j)];
            }
        }
    }
    Some(inv)
}

/// Lemma-3 preconditioner: returns (m_hat, d_inv_sqrt) with
/// `m_hat = D^{-1/2} (M + gamma I) D^{-1/2}`, `D = diag((M+gamma I) 1)`.
pub fn ns_preconditioner(m: &Matrix, gamma: f32) -> (Matrix, Vec<f32>) {
    assert_eq!(m.rows, m.cols);
    let n = m.rows;
    let mg = m.add_diag(gamma);
    let d_inv_sqrt: Vec<f32> = (0..n)
        .map(|i| {
            let row_sum: f32 = mg.row(i).iter().sum();
            1.0 / row_sum.max(1e-30).sqrt()
        })
        .collect();
    let m_hat = Matrix::from_fn(n, n, |i, j| d_inv_sqrt[i] * mg[(i, j)] * d_inv_sqrt[j]);
    (m_hat, d_inv_sqrt)
}

/// Preconditioned Newton–Schulz approximation of `(M + gamma I)^{-1}`
/// (paper §4.4): the order-3 hyperpower iteration
/// `Z <- 1/4 Z (13 I - A Z (15 I - A Z (7 I - A Z)))`, seeded with
/// `Z0 = A^T / (||A||_1 ||A||_inf)`.
pub fn ns_inverse(m: &Matrix, gamma: f32, iters: usize) -> Matrix {
    let _span = obs::span("nystrom", "ns_inverse");
    let n = m.rows;
    let (a, d_inv_sqrt) = ns_preconditioner(m, gamma);
    let eye = Matrix::eye(n);

    let norm1 = (0..n)
        .map(|j| (0..n).map(|i| a[(i, j)].abs()).sum::<f32>())
        .fold(0.0f32, f32::max);
    let norminf = (0..n)
        .map(|i| a.row(i).iter().map(|x| x.abs()).sum::<f32>())
        .fold(0.0f32, f32::max);
    let mut z = a.transpose().scale(1.0 / (norm1 * norminf).max(1e-30));

    let mut residual = f32::NAN;
    for iter in 0..iters {
        let az = a.matmul(&z);
        // convergence diagnostic ||AZ - I||_max — az is already in hand,
        // so this is one cheap pass; only taken when tracing is on
        if obs::enabled() {
            residual = az.sub(&eye).max_abs();
            obs::event(
                "nystrom",
                "ns_iter",
                Some(json::obj(vec![
                    ("iter", json::num(iter as f64)),
                    ("residual", json::num(residual as f64)),
                ])),
            );
            obs::observe("ns_iter_residual", residual as f64);
        }
        let t1 = eye.scale(7.0).sub(&az);
        let t2 = eye.scale(15.0).sub(&az.matmul(&t1));
        let t3 = eye.scale(13.0).sub(&az.matmul(&t2));
        z = z.matmul(&t3).scale(0.25);
    }
    if obs::enabled() && residual.is_finite() {
        obs::gauge_set("ns_final_residual", residual as f64);
    }
    // undo preconditioning: (M+gI)^{-1} = D^{-1/2} Z D^{-1/2}
    Matrix::from_fn(n, n, |i, j| d_inv_sqrt[i] * z[(i, j)] * d_inv_sqrt[j])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_psd(seed: u64, n: usize) -> Matrix {
        let mut rng = Rng::new(seed);
        let b = Matrix::randn(&mut rng, n, n, 1.0);
        b.matmul(&b.transpose()).scale(1.0 / n as f32).add_diag(0.1)
    }

    #[test]
    fn gauss_jordan_inverts() {
        let m = random_psd(0, 24);
        let inv = gauss_jordan_inverse(&m).unwrap();
        let prod = m.matmul(&inv);
        let err = prod.sub(&Matrix::eye(24)).max_abs();
        assert!(err < 1e-3, "err {err}");
    }

    #[test]
    fn gauss_jordan_rejects_singular() {
        let m = Matrix::from_rows(vec![vec![1.0, 2.0], vec![2.0, 4.0]]);
        assert!(gauss_jordan_inverse(&m).is_none());
    }

    fn gaussian_gram(seed: u64, n: usize, p: usize) -> Matrix {
        // Lemma 3's preconditioner assumes a *kernel* matrix (non-negative
        // entries) — that is the only input class the paper feeds it.
        let mut rng = Rng::new(seed);
        let x = Matrix::randn(&mut rng, n, p, 0.5);
        crate::nystrom::kernel_matrix(crate::nystrom::Kernel::Gaussian, &x, &x)
    }

    #[test]
    fn ns_matches_exact_inverse() {
        let m = gaussian_gram(1, 32, 8);
        let gamma = 1e-3;
        let exact = gauss_jordan_inverse(&m.add_diag(gamma)).unwrap();
        let approx = ns_inverse(&m, gamma, 30);
        let scale = exact.max_abs();
        let err = exact.sub(&approx).max_abs() / scale;
        assert!(err < 2e-3, "relative err {err}");
    }

    #[test]
    fn preconditioner_spectrum_in_unit_interval() {
        // Lemma 3 numerically: ||I - m_hat||_2 < 1
        let m = random_psd(2, 40);
        // make it look like a kernel matrix (positive entries)
        let k = Matrix::from_fn(40, 40, |i, j| (-0.05 * (m[(i, j)] - m[(j, i)]).abs()).exp() * (m[(i, j)].abs() + 0.1));
        let sym = k.add(&k.transpose()).scale(0.5);
        let psd = sym.matmul(&sym.transpose()).scale(1.0 / 40.0);
        let (m_hat, _) = ns_preconditioner(&psd, 1e-3);
        let resid = crate::linalg::norms::spectral_norm(&Matrix::eye(40).sub(&m_hat));
        assert!(resid < 1.0 + 1e-4, "resid {resid}");
    }
}
