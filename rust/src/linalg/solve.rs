//! Exact and iterative inverses on the dense substrate.
//!
//! * [`gauss_jordan_inverse`] — partial-pivot exact inverse (the "CPU
//!   division-based" method of the paper's §4.4 discussion; used as the
//!   oracle the Newton–Schulz iteration is judged against).
//! * [`ns_inverse`] — the paper's preconditioned Newton–Schulz: the native
//!   twin of the L1 Pallas kernel, used by the Figure-1 study.  The
//!   iteration count is adaptive: the `ns_final_residual` trail showed the
//!   residual either converges well before the fixed count or hits the f32
//!   floor and jitters, so the loop stops at [`NS_TOL`] or on the first
//!   non-improving step ([`ns_inverse_with_stats`] reports which).

use crate::kernels::{self, KernelCtx};
use crate::linalg::Matrix;
use crate::obs;
use crate::util::json;

/// Exact inverse by Gauss–Jordan with partial pivoting. Returns `None` if
/// the matrix is numerically singular.
pub fn gauss_jordan_inverse(m: &Matrix) -> Option<Matrix> {
    assert_eq!(m.rows, m.cols);
    let n = m.rows;
    let mut a = m.clone();
    let mut inv = Matrix::eye(n);
    for col in 0..n {
        // pivot
        let mut piv = col;
        let mut best = a[(col, col)].abs();
        for r in col + 1..n {
            if a[(r, col)].abs() > best {
                best = a[(r, col)].abs();
                piv = r;
            }
        }
        if best < 1e-12 {
            return None;
        }
        if piv != col {
            for j in 0..n {
                let t = a[(col, j)];
                a[(col, j)] = a[(piv, j)];
                a[(piv, j)] = t;
                let t = inv[(col, j)];
                inv[(col, j)] = inv[(piv, j)];
                inv[(piv, j)] = t;
            }
        }
        let d = a[(col, col)];
        for j in 0..n {
            a[(col, j)] /= d;
            inv[(col, j)] /= d;
        }
        for r in 0..n {
            if r == col {
                continue;
            }
            let f = a[(r, col)];
            if f == 0.0 {
                continue;
            }
            for j in 0..n {
                a[(r, j)] -= f * a[(col, j)];
                inv[(r, j)] -= f * inv[(col, j)];
            }
        }
    }
    Some(inv)
}

/// Lemma-3 preconditioner: returns (m_hat, d_inv_sqrt) with
/// `m_hat = D^{-1/2} (M + gamma I) D^{-1/2}`, `D = diag((M+gamma I) 1)`.
pub fn ns_preconditioner(m: &Matrix, gamma: f32) -> (Matrix, Vec<f32>) {
    assert_eq!(m.rows, m.cols);
    let n = m.rows;
    let mg = m.add_diag(gamma);
    let d_inv_sqrt: Vec<f32> = (0..n)
        .map(|i| {
            let row_sum: f32 = mg.row(i).iter().sum();
            1.0 / row_sum.max(1e-30).sqrt()
        })
        .collect();
    let m_hat = Matrix::from_fn(n, n, |i, j| d_inv_sqrt[i] * mg[(i, j)] * d_inv_sqrt[j]);
    (m_hat, d_inv_sqrt)
}

/// Adaptive loop: stop once `||AZ - I||_max` drops to this level —
/// further order-3 steps only churn f32 noise.
pub const NS_TOL: f32 = 1e-6;

/// What the adaptive Newton–Schulz loop actually did.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NsStats {
    /// Hyperpower updates applied (<= the `iters` cap).
    pub iters_run: usize,
    /// `||AZ - I||_max` of the returned (preconditioned) iterate at the
    /// last measurement.
    pub final_residual: f32,
    /// Stopped because the residual reached [`NS_TOL`].
    pub converged: bool,
    /// Stopped because the residual stopped improving (f32 floor or
    /// divergence); the previous — at least as good — iterate is kept.
    pub stalled: bool,
}

/// Preconditioned Newton–Schulz approximation of `(M + gamma I)^{-1}`
/// (paper §4.4): the order-3 hyperpower iteration
/// `Z <- 1/4 Z (13 I - A Z (15 I - A Z (7 I - A Z)))`, seeded with
/// `Z0 = A^T / (||A||_1 ||A||_inf)`.  `iters` caps the loop; the
/// residual trail stops it early on convergence or stall (see
/// [`ns_inverse_with_stats`] for the outcome).
pub fn ns_inverse(m: &Matrix, gamma: f32, iters: usize) -> Matrix {
    ns_inverse_with_stats(m, gamma, iters).0
}

/// [`ns_inverse`] plus the adaptive-stopping diagnostics.  The stop rule
/// depends only on the input data (never on timing), so iteration counts
/// — like the kernel outputs themselves — are identical across thread
/// counts.
pub fn ns_inverse_with_stats(m: &Matrix, gamma: f32, iters: usize) -> (Matrix, NsStats) {
    let _span = obs::span("nystrom", "ns_inverse");
    let ctx = KernelCtx::global();
    let n = m.rows;
    let (a, d_inv_sqrt) = ns_preconditioner(m, gamma);
    let eye = Matrix::eye(n);

    let norm1 = (0..n)
        .map(|j| (0..n).map(|i| a[(i, j)].abs()).sum::<f32>())
        .fold(0.0f32, f32::max);
    let norminf = (0..n)
        .map(|i| a.row(i).iter().map(|x| x.abs()).sum::<f32>())
        .fold(0.0f32, f32::max);
    // transpose_scale fuses the seed into one pass; bit-identical to
    // a.transpose().scale(..) (each element is a single product)
    let mut z = a.transpose_scale(1.0 / (norm1 * norminf).max(1e-30));

    let mut stats = NsStats {
        iters_run: 0,
        final_residual: f32::INFINITY,
        converged: false,
        stalled: false,
    };
    let mut prev_residual = f32::INFINITY;
    let mut prev_z: Option<Matrix> = None;
    for iter in 0..iters {
        let az = a.matmul(&z);
        // residual of the *current* iterate, ||AZ - I||_max — az is in
        // hand, so this is one cheap O(n^2) pass per O(n^3) step
        let mut residual = 0.0f32;
        for i in 0..n {
            for (j, &v) in az.row(i).iter().enumerate() {
                let d = if i == j { v - 1.0 } else { v };
                residual = residual.max(d.abs());
            }
        }
        obs::observe("ns_iter_residual", residual as f64);
        if obs::enabled() {
            obs::event(
                "nystrom",
                "ns_iter",
                Some(json::obj(vec![
                    ("iter", json::num(iter as f64)),
                    ("residual", json::num(residual as f64)),
                ])),
            );
        }
        stats.final_residual = residual;
        if residual <= NS_TOL {
            stats.converged = true;
            break;
        }
        if !residual.is_finite() || residual >= prev_residual {
            // f32 floor reached (or diverging): the previous iterate was
            // at least as good — roll back and stop
            stats.stalled = true;
            if let Some(prev) = prev_z {
                z = prev;
                stats.final_residual = prev_residual;
            }
            break;
        }
        prev_residual = residual;
        prev_z = Some(z.clone());
        let t1 = kernels::scale_add(ctx, &eye, 7.0, &az, -1.0);
        let t2 = kernels::scale_add(ctx, &eye, 15.0, &az.matmul(&t1), -1.0);
        let t3 = kernels::scale_add(ctx, &eye, 13.0, &az.matmul(&t2), -1.0);
        z = z.matmul(&t3).scale(0.25);
        stats.iters_run = iter + 1;
    }
    if stats.final_residual.is_finite() {
        obs::gauge_set("ns_final_residual", stats.final_residual as f64);
    }
    obs::gauge_set("ns_iters_used", stats.iters_run as f64);
    if stats.converged || stats.stalled {
        obs::counter_add("ns_early_stops_total", 1);
    }
    // undo preconditioning: (M+gI)^{-1} = D^{-1/2} Z D^{-1/2}
    let inv = Matrix::from_fn(n, n, |i, j| d_inv_sqrt[i] * z[(i, j)] * d_inv_sqrt[j]);
    (inv, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_psd(seed: u64, n: usize) -> Matrix {
        let mut rng = Rng::new(seed);
        let b = Matrix::randn(&mut rng, n, n, 1.0);
        b.matmul(&b.transpose()).scale(1.0 / n as f32).add_diag(0.1)
    }

    #[test]
    fn gauss_jordan_inverts() {
        let m = random_psd(0, 24);
        let inv = gauss_jordan_inverse(&m).unwrap();
        let prod = m.matmul(&inv);
        let err = prod.sub(&Matrix::eye(24)).max_abs();
        assert!(err < 1e-3, "err {err}");
    }

    #[test]
    fn gauss_jordan_rejects_singular() {
        let m = Matrix::from_rows(vec![vec![1.0, 2.0], vec![2.0, 4.0]]);
        assert!(gauss_jordan_inverse(&m).is_none());
    }

    fn gaussian_gram(seed: u64, n: usize, p: usize) -> Matrix {
        // Lemma 3's preconditioner assumes a *kernel* matrix (non-negative
        // entries) — that is the only input class the paper feeds it.
        let mut rng = Rng::new(seed);
        let x = Matrix::randn(&mut rng, n, p, 0.5);
        crate::nystrom::kernel_matrix(crate::nystrom::Kernel::Gaussian, &x, &x)
    }

    #[test]
    fn ns_matches_exact_inverse() {
        let m = gaussian_gram(1, 32, 8);
        let gamma = 1e-3;
        let exact = gauss_jordan_inverse(&m.add_diag(gamma)).unwrap();
        let approx = ns_inverse(&m, gamma, 30);
        let scale = exact.max_abs();
        let err = exact.sub(&approx).max_abs() / scale;
        assert!(err < 2e-3, "relative err {err}");
    }

    #[test]
    fn ns_stops_early_on_well_conditioned_gram() {
        // order-3 convergence on a preconditioned kernel Gram is fast:
        // the loop must hit NS_TOL or the f32 floor long before the cap,
        // and the result must still match the exact inverse
        let m = gaussian_gram(6, 32, 8);
        let gamma = 1e-3;
        let (approx, stats) = ns_inverse_with_stats(&m, gamma, 1000);
        assert!(
            stats.converged || stats.stalled,
            "no early stop in 1000 iters: {stats:?}"
        );
        assert!(stats.iters_run < 100, "iters_run {}", stats.iters_run);
        let exact = gauss_jordan_inverse(&m.add_diag(gamma)).unwrap();
        let err = exact.sub(&approx).max_abs() / exact.max_abs();
        assert!(err < 2e-3, "relative err {err}");
    }

    #[test]
    fn ns_adaptive_matches_or_beats_fixed_count() {
        // the adaptive loop must be at least as accurate as the old fixed
        // 30-iteration run (it only ever stops at the tolerance or keeps
        // the best iterate seen)
        let m = gaussian_gram(7, 24, 6);
        let gamma = 1e-3;
        let (_, stats) = ns_inverse_with_stats(&m, gamma, 30);
        assert!(
            stats.final_residual <= NS_TOL || stats.stalled || stats.iters_run == 30,
            "loop exited without a recorded reason: {stats:?}"
        );
        assert!(stats.final_residual.is_finite());
    }

    #[test]
    fn ns_transpose_free_seed_reproduces_materialised_output() {
        // ns_inverse exactly as it was before the transpose-free seed
        // refactor (materialised `a.transpose().scale(..)`), minus obs:
        // the fused seed is the same single product per element, so the
        // full adaptive iteration — residual trail, early stops and all —
        // must reproduce the production output bit-for-bit
        fn ns_inverse_materialised(m: &Matrix, gamma: f32, iters: usize) -> Matrix {
            let ctx = KernelCtx::global();
            let n = m.rows;
            let (a, d_inv_sqrt) = ns_preconditioner(m, gamma);
            let eye = Matrix::eye(n);
            let norm1 = (0..n)
                .map(|j| (0..n).map(|i| a[(i, j)].abs()).sum::<f32>())
                .fold(0.0f32, f32::max);
            let norminf = (0..n)
                .map(|i| a.row(i).iter().map(|x| x.abs()).sum::<f32>())
                .fold(0.0f32, f32::max);
            let mut z = a.transpose().scale(1.0 / (norm1 * norminf).max(1e-30));
            let mut prev_residual = f32::INFINITY;
            let mut prev_z: Option<Matrix> = None;
            for _ in 0..iters {
                let az = a.matmul(&z);
                let mut residual = 0.0f32;
                for i in 0..n {
                    for (j, &v) in az.row(i).iter().enumerate() {
                        let d = if i == j { v - 1.0 } else { v };
                        residual = residual.max(d.abs());
                    }
                }
                if residual <= NS_TOL {
                    break;
                }
                if !residual.is_finite() || residual >= prev_residual {
                    if let Some(prev) = prev_z {
                        z = prev;
                    }
                    break;
                }
                prev_residual = residual;
                prev_z = Some(z.clone());
                let t1 = kernels::scale_add(ctx, &eye, 7.0, &az, -1.0);
                let t2 = kernels::scale_add(ctx, &eye, 15.0, &az.matmul(&t1), -1.0);
                let t3 = kernels::scale_add(ctx, &eye, 13.0, &az.matmul(&t2), -1.0);
                z = z.matmul(&t3).scale(0.25);
            }
            Matrix::from_fn(n, n, |i, j| d_inv_sqrt[i] * z[(i, j)] * d_inv_sqrt[j])
        }

        let m = gaussian_gram(9, 32, 8);
        let got = ns_inverse(&m, 1e-3, 12);
        let want = ns_inverse_materialised(&m, 1e-3, 12);
        for (x, y) in got.data.iter().zip(&want.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn ns_cap_of_zero_returns_seed() {
        let m = gaussian_gram(8, 16, 4);
        let (z, stats) = ns_inverse_with_stats(&m, 1e-3, 0);
        assert_eq!(stats.iters_run, 0);
        assert!(!stats.converged && !stats.stalled);
        assert!(z.is_finite());
    }

    #[test]
    fn preconditioner_spectrum_in_unit_interval() {
        // Lemma 3 numerically: ||I - m_hat||_2 < 1
        let m = random_psd(2, 40);
        // make it look like a kernel matrix (positive entries)
        let k = Matrix::from_fn(40, 40, |i, j| (-0.05 * (m[(i, j)] - m[(j, i)]).abs()).exp() * (m[(i, j)].abs() + 0.1));
        let sym = k.add(&k.transpose()).scale(0.5);
        let psd = sym.matmul(&sym.transpose()).scale(1.0 / 40.0);
        let (m_hat, _) = ns_preconditioner(&psd, 1e-3);
        let resid = crate::linalg::norms::spectral_norm(&Matrix::eye(40).sub(&m_hat));
        assert!(resid < 1.0 + 1e-4, "resid {resid}");
    }
}
