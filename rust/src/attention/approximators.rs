//! The approximation methods of Figure 1, in native rust.
//!
//! Every method maps (q, k, v, num_features, rng) to an approximate
//! *softmax-attention output* `~ D^{-1} A V`.  Untrained projections
//! (Linformer) are random — matching the paper's Figure-1 protocol, where
//! weights come from initialized/pretrained BERT but the approximator's own
//! parameters are freshly sampled.

use crate::attention::exact::{row_softmax, softmax_attention};
use crate::kernels::{self, KernelCtx};
use crate::linalg::Matrix;
use crate::nystrom::{self, Inverse, Kernel};
use crate::obs;
use crate::util::rng::Rng;

/// The methods of the study (Figure 1's legend).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// Modified Nyström on the un-normalised score matrix A (the paper's
    /// "Skyformer" series in Figure 1: approximate A, then D, then D^{-1}AV).
    Skyformer,
    /// Nyströmformer: Nyström directly on the softmax matrix with
    /// segment-mean landmarks (the non-PSD usage the paper critiques).
    Nystromformer,
    Linformer,
    Performer,
    Informer,
    Reformer,
    BigBird,
}

pub const METHODS: [Method; 7] = [
    Method::Skyformer,
    Method::Nystromformer,
    Method::Linformer,
    Method::Performer,
    Method::Informer,
    Method::Reformer,
    Method::BigBird,
];

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Skyformer => "skyformer",
            Method::Nystromformer => "nystromformer",
            Method::Linformer => "linformer",
            Method::Performer => "performer",
            Method::Informer => "informer",
            Method::Reformer => "reformer",
            Method::BigBird => "bigbird",
        }
    }

    pub fn parse(name: &str) -> Option<Method> {
        METHODS.iter().copied().find(|m| m.name() == name)
    }
}

/// Dispatch: approximate softmax attention output with `d` features.
pub fn approximate(
    method: Method,
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    d: usize,
    rng: &mut Rng,
) -> Matrix {
    let _span = obs::span("attention", method.name());
    match method {
        Method::Skyformer => skyformer(q, k, v, d, rng),
        Method::Nystromformer => nystromformer(q, k, v, d),
        Method::Linformer => linformer(q, k, v, d, rng),
        Method::Performer => performer(q, k, v, d, rng),
        Method::Informer => informer(q, k, v, d, rng),
        Method::Reformer => reformer(q, k, v, d, rng),
        Method::BigBird => bigbird(q, k, v, d, rng),
    }
}

/// Figure-1 "Skyformer": modified Nyström (SM kernel, PSD lift) on A;
/// D is recovered from the approximation (A_tilde 1), as Performer does.
fn skyformer(q: &Matrix, k: &Matrix, v: &Matrix, d: usize, rng: &mut Rng) -> Matrix {
    let landmarks = rng.choose_distinct(q.rows + k.rows, d.min(q.rows + k.rows));
    let a_tilde = nystrom::modified_nystrom_with_landmarks(
        Kernel::Softmax,
        q,
        k,
        &landmarks,
        Inverse::NewtonSchulz { gamma: 1e-3, iters: 10 },
    );
    normalize_rows_apply(&a_tilde, v)
}

/// The actual Skyformer model output `C_tilde V` (Gaussian kernel) —
/// approximates Kernelized Attention, exposed for the KA-target study.
pub fn skyformer_gaussian(q: &Matrix, k: &Matrix, v: &Matrix, d: usize, rng: &mut Rng) -> Matrix {
    let landmarks = rng.choose_distinct(q.rows + k.rows, d.min(q.rows + k.rows));
    nystrom::modified_nystrom_apply(
        Kernel::Gaussian,
        q,
        k,
        v,
        &landmarks,
        Inverse::NewtonSchulz { gamma: 1e-3, iters: 10 },
    )
}

fn normalize_rows_apply(a: &Matrix, v: &Matrix) -> Matrix {
    // D^{-1} A V with D = diag(A 1); guard against tiny/negative rows
    let mut out = a.matmul(v);
    for i in 0..a.rows {
        let s: f32 = a.row(i).iter().sum();
        let inv = 1.0 / s.abs().max(1e-6) * s.signum();
        for x in out.row_mut(i) {
            *x *= inv;
        }
    }
    out
}

/// Nyströmformer (Xiong et al.): segment-mean landmarks, softmax blocks,
/// iterative pinv on the (non-PSD) middle block.  The n-sized factors go
/// through the fused kernels: `q lk^T` never materialises a transpose and
/// the leading `softmax(·) @ rest` never materialises the softmax matrix.
fn nystromformer(q: &Matrix, k: &Matrix, v: &Matrix, d: usize) -> Matrix {
    let ctx = KernelCtx::global();
    let lq = segment_means(q, d);
    let lk = segment_means(k, d);
    let a = row_softmax(&kernels::matmul_transb(ctx, &lq, &lk)); // (d, d)
    let f3 = row_softmax(&kernels::matmul_transb(ctx, &lq, k)); // (d, m)
    let z = hyperpower_pinv(&a, 10);
    let rest = z.matmul(&f3.matmul(v)); // (d, dv)
    let s1 = kernels::matmul_transb(ctx, q, &lk); // (n, d)
    kernels::row_softmax_matmul(ctx, &s1, &rest)
}

fn segment_means(x: &Matrix, num: usize) -> Matrix {
    let num = num.min(x.rows).max(1);
    let base = x.rows / num;
    let extra = x.rows % num;
    let mut out = Matrix::zeros(num, x.cols);
    let mut row = 0usize;
    for s in 0..num {
        let len = base + usize::from(s < extra);
        let len = len.max(1);
        for _ in 0..len {
            if row >= x.rows {
                break;
            }
            for j in 0..x.cols {
                out[(s, j)] += x[(row, j)];
            }
            row += 1;
        }
        for j in 0..x.cols {
            out[(s, j)] /= len as f32;
        }
    }
    out
}

/// Nyströmformer's unpreconditioned hyperpower pinv (their released init).
fn hyperpower_pinv(a: &Matrix, iters: usize) -> Matrix {
    let n = a.rows;
    let eye = Matrix::eye(n);
    let norm1 = (0..n)
        .map(|j| (0..n).map(|i| a[(i, j)].abs()).sum::<f32>())
        .fold(0.0f32, f32::max);
    let norminf = (0..n)
        .map(|i| a.row(i).iter().map(|x| x.abs()).sum::<f32>())
        .fold(0.0f32, f32::max);
    // fused seed, bit-identical to a.transpose().scale(..)
    let mut z = a.transpose_scale(1.0 / (norm1 * norminf).max(1e-30));
    for _ in 0..iters {
        let az = a.matmul(&z);
        let t1 = eye.scale(7.0).sub(&az);
        let t2 = eye.scale(15.0).sub(&az.matmul(&t1));
        let t3 = eye.scale(13.0).sub(&az.matmul(&t2));
        z = z.matmul(&t3).scale(0.25);
    }
    z
}

/// Linformer: random JL projections E, F (d x m) compressing keys/values.
fn linformer(q: &Matrix, k: &Matrix, v: &Matrix, d: usize, rng: &mut Rng) -> Matrix {
    let m = k.rows;
    let scale = 1.0 / (m as f32).sqrt();
    let e = Matrix::randn(rng, d.min(m), m, scale);
    let f = Matrix::randn(rng, d.min(m), m, scale);
    let ke = e.matmul(k); // (d, p)
    let vf = f.matmul(v); // (d, dv)
    // structurally plain attention against the compressed keys/values —
    // reuse the fused softmax(q ke^T) vf path
    softmax_attention(q, &ke, &vf)
}

/// Performer / FAVOR+: positive orthogonal random features for SM.
fn performer(q: &Matrix, k: &Matrix, v: &Matrix, d: usize, rng: &mut Rng) -> Matrix {
    let ctx = KernelCtx::global();
    let p = q.cols;
    let w = orthogonal_features(rng, d, p);
    let pq = favor_phi(q, &w);
    let pk = favor_phi(k, &w);
    // out = phi(q) (phi(k)^T v) / (phi(q) phi(k)^T 1)
    let kv = kernels::matmul_transa(ctx, &pk, &v); // (d, dv), no phi(k)^T copy
    let num = pq.matmul(&kv); // (n, dv)
    let ksum: Vec<f32> = (0..d).map(|j| (0..pk.rows).map(|i| pk[(i, j)]).sum()).collect();
    let den = pq.matvec(&ksum); // (n,)
    let mut out = num;
    for i in 0..out.rows {
        let inv = 1.0 / den[i].max(1e-6);
        for x in out.row_mut(i) {
            *x *= inv;
        }
    }
    out
}

fn favor_phi(x: &Matrix, w: &Matrix) -> Matrix {
    // phi(x) = exp(w.x - |x|^2/2) / sqrt(m), with a global max-subtraction
    let proj = kernels::matmul_transb(KernelCtx::global(), x, w); // (n, m), no w^T copy
    let m = w.rows as f32;
    let mut z = Matrix::zeros(proj.rows, proj.cols);
    let mut zmax = f32::NEG_INFINITY;
    for i in 0..proj.rows {
        let sq: f32 = 0.5 * x.row(i).iter().map(|a| a * a).sum::<f32>();
        for j in 0..proj.cols {
            let e = proj[(i, j)] - sq;
            z[(i, j)] = e;
            zmax = zmax.max(e);
        }
    }
    for val in &mut z.data {
        *val = (*val - zmax).exp() / m.sqrt();
    }
    z
}

fn orthogonal_features(rng: &mut Rng, m: usize, p: usize) -> Matrix {
    // QR of gaussian blocks via Gram-Schmidt, chi-resampled row norms
    let mut rows: Vec<Vec<f32>> = Vec::with_capacity(m);
    while rows.len() < m {
        let block = (rows.len() / p) * p; // start of this block
        let in_block = rows.len() - block;
        let mut v: Vec<f32> = (0..p).map(|_| rng.normal()).collect();
        // orthogonalise against this block only
        for prev in rows[block..block + in_block].iter() {
            let dot: f32 = v.iter().zip(prev).map(|(a, b)| a * b).sum();
            for (x, &pv) in v.iter_mut().zip(prev) {
                *x -= dot * pv;
            }
        }
        let norm: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        if norm < 1e-6 {
            continue; // resample degenerate draw
        }
        for x in &mut v {
            *x /= norm;
        }
        rows.push(v);
    }
    // chi(p) row norms restore the gaussian marginals
    let mut w = Matrix::from_rows(rows);
    for i in 0..m {
        let chi: f32 = (0..p).map(|_| rng.normal().powi(2)).sum::<f32>().sqrt();
        for x in w.row_mut(i) {
            *x *= chi;
        }
    }
    w
}

/// Informer ProbSparse: top-u queries (by max-mean sparsity measure on a
/// key sample) get full attention; the rest emit mean(V).
fn informer(q: &Matrix, k: &Matrix, v: &Matrix, d: usize, rng: &mut Rng) -> Matrix {
    let n = q.rows;
    let m = k.rows;
    let u = d.min(n);
    let su = d.min(m);
    let sample_idx = rng.choose_distinct(m, su);
    let ks = k.take_rows(&sample_idx);
    let meas = kernels::matmul_transb(KernelCtx::global(), q, &ks); // (n, su), no k^T copy
    let mut sparsity: Vec<(f32, usize)> = (0..n)
        .map(|i| {
            let row = meas.row(i);
            let max = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let mean: f32 = row.iter().sum::<f32>() / su as f32;
            (max - mean, i)
        })
        .collect();
    sparsity.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let top: Vec<usize> = sparsity[..u].iter().map(|&(_, i)| i).collect();

    // baseline: mean of V
    let mut out = Matrix::zeros(n, v.cols);
    let mut mean_v = vec![0.0f32; v.cols];
    for i in 0..m {
        for j in 0..v.cols {
            mean_v[j] += v[(i, j)];
        }
    }
    for x in &mut mean_v {
        *x /= m as f32;
    }
    for i in 0..n {
        out.row_mut(i).copy_from_slice(&mean_v);
    }
    // full attention for the selected queries
    let qt = q.take_rows(&top);
    let attn = softmax_attention(&qt, k, v);
    for (r, &i) in top.iter().enumerate() {
        out.row_mut(i).copy_from_slice(attn.row(r));
    }
    out
}

/// Reformer-style LSH: random-rotation buckets on (q + k), sort, chunked
/// attention over own + previous chunk (chunk = d/2 keys visible per query).
fn reformer(q: &Matrix, k: &Matrix, v: &Matrix, d: usize, rng: &mut Rng) -> Matrix {
    let n = q.rows;
    assert_eq!(k.rows, n, "reformer assumes aligned q/k positions");
    let chunk = (d / 2).clamp(1, n);
    let n_buckets = (n / chunk).max(2);
    let p = q.cols;
    let r = Matrix::randn(rng, p, n_buckets, 1.0);
    // bucket by argmax over [xR, -xR]
    let joint = Matrix::from_fn(n, p, |i, j| q[(i, j)] + k[(i, j)]);
    let logits = joint.matmul(&r);
    let mut order: Vec<usize> = (0..n).collect();
    let bucket_of = |i: usize| -> usize {
        let row = logits.row(i);
        let mut best = (f32::NEG_INFINITY, 0usize);
        for (b, &x) in row.iter().enumerate() {
            if x > best.0 {
                best = (x, b);
            }
            if -x > best.0 {
                best = (-x, b + n_buckets);
            }
        }
        best.1
    };
    let buckets: Vec<usize> = (0..n).map(bucket_of).collect();
    order.sort_by_key(|&i| (buckets[i], i));

    let mut out = Matrix::zeros(n, v.cols);
    let n_chunks = n.div_ceil(chunk);
    for c in 0..n_chunks {
        let qs: Vec<usize> = (c * chunk..((c + 1) * chunk).min(n))
            .map(|r| order[r])
            .collect();
        // keys: previous chunk (wrap) + own chunk
        let prev = if c == 0 { n_chunks - 1 } else { c - 1 };
        let mut kidx: Vec<usize> = (prev * chunk..((prev + 1) * chunk).min(n))
            .map(|r| order[r])
            .collect();
        kidx.extend(qs.iter().copied());
        let qm = q.take_rows(&qs);
        let km = k.take_rows(&kidx);
        let vm = v.take_rows(&kidx);
        let o = softmax_attention(&qm, &km, &vm);
        for (r, &i) in qs.iter().enumerate() {
            out.row_mut(i).copy_from_slice(o.row(r));
        }
    }
    out
}

/// BigBird-style block sparse: global block 0, window {i-1, i, i+1}, and
/// random blocks; block size chosen so each query sees ~d keys.
fn bigbird(q: &Matrix, k: &Matrix, v: &Matrix, d: usize, rng: &mut Rng) -> Matrix {
    let n = q.rows;
    assert_eq!(k.rows, n, "bigbird assumes aligned q/k positions");
    let b = (d / 6).clamp(1, n); // 6 blocks visible => ~d keys
    let nb = n.div_ceil(b);
    let mut out = Matrix::zeros(n, v.cols);
    for blk in 0..nb {
        let qs: Vec<usize> = (blk * b..((blk + 1) * b).min(n)).collect();
        let mut sel = vec![0usize, blk.saturating_sub(1), blk, (blk + 1) % nb];
        sel.push(rng.below(nb));
        sel.push(rng.below(nb));
        sel.sort_unstable();
        sel.dedup();
        let mut kidx = Vec::new();
        for &s in &sel {
            kidx.extend(s * b..((s + 1) * b).min(n));
        }
        let qm = q.take_rows(&qs);
        let km = k.take_rows(&kidx);
        let vm = v.take_rows(&kidx);
        let o = softmax_attention(&qm, &km, &vm);
        for (r, &i) in qs.iter().enumerate() {
            out.row_mut(i).copy_from_slice(o.row(r));
        }
    }
    // global block queries see everything
    let g: Vec<usize> = (0..b.min(n)).collect();
    let qg = q.take_rows(&g);
    let og = softmax_attention(&qg, k, v);
    for (r, &i) in g.iter().enumerate() {
        out.row_mut(i).copy_from_slice(og.row(r));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::exact;
    use crate::linalg::norms::relative_spectral_error;

    fn qkv(seed: u64, n: usize, p: usize) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let scale = (p as f32).powf(-0.25) * 0.8;
        let q = Matrix::randn(&mut rng, n, p, scale);
        let k = Matrix::randn(&mut rng, n, p, scale);
        let v = Matrix::randn(&mut rng, n, p, 1.0);
        (q, k, v)
    }

    #[test]
    fn all_methods_produce_finite_right_shape() {
        let (q, k, v) = qkv(0, 64, 16);
        for m in METHODS {
            let mut rng = Rng::new(1);
            let out = approximate(m, &q, &k, &v, 16, &mut rng);
            assert_eq!((out.rows, out.cols), (64, 16), "{}", m.name());
            assert!(out.is_finite(), "{}", m.name());
        }
    }

    #[test]
    fn skyformer_error_decreases_with_features() {
        let (q, k, v) = qkv(2, 96, 16);
        let target = exact::softmax_attention(&q, &k, &v);
        let err = |d: usize| -> f32 {
            let mut acc = 0.0;
            for s in 0..3 {
                let mut rng = Rng::new(50 + s);
                let approx = approximate(Method::Skyformer, &q, &k, &v, d, &mut rng);
                acc += relative_spectral_error(&target, &approx);
            }
            acc / 3.0
        };
        let (e_small, e_large) = (err(8), err(128));
        assert!(
            e_large < e_small * 0.7,
            "skyformer error flat: {e_small} -> {e_large}"
        );
    }

    #[test]
    fn performer_is_unbiasedish_at_high_features() {
        let (q, k, v) = qkv(3, 48, 8);
        let target = exact::softmax_attention(&q, &k, &v);
        let mut rng = Rng::new(9);
        let approx = approximate(Method::Performer, &q, &k, &v, 512, &mut rng);
        let rel = relative_spectral_error(&target, &approx);
        assert!(rel < 0.5, "performer rel err {rel}");
    }

    #[test]
    fn informer_covers_all_queries_at_full_budget() {
        let (q, k, v) = qkv(4, 32, 8);
        let target = exact::softmax_attention(&q, &k, &v);
        let mut rng = Rng::new(5);
        let approx = approximate(Method::Informer, &q, &k, &v, 32, &mut rng);
        let rel = relative_spectral_error(&target, &approx);
        assert!(rel < 1e-3, "at u=n informer must equal exact, rel {rel}");
    }

    #[test]
    fn skyformer_gaussian_approximates_kernelized() {
        let (q, k, v) = qkv(6, 80, 16);
        let target = exact::kernelized_attention(&q, &k, &v);
        let mut rng = Rng::new(7);
        let approx = skyformer_gaussian(&q, &k, &v, 160, &mut rng);
        let rel = relative_spectral_error(&target, &approx);
        assert!(rel < 0.35, "rel {rel}");
    }

    /// The hyperpower pinv exactly as it was before the transpose-free
    /// refactor: seeded with a materialised `a.transpose().scale(..)`.
    /// Kept verbatim as the capture of the pre-refactor pipeline.
    fn hyperpower_pinv_materialised(a: &Matrix, iters: usize) -> Matrix {
        let n = a.rows;
        let eye = Matrix::eye(n);
        let norm1 = (0..n)
            .map(|j| (0..n).map(|i| a[(i, j)].abs()).sum::<f32>())
            .fold(0.0f32, f32::max);
        let norminf = (0..n)
            .map(|i| a.row(i).iter().map(|x| x.abs()).sum::<f32>())
            .fold(0.0f32, f32::max);
        let mut z = a.transpose().scale(1.0 / (norm1 * norminf).max(1e-30));
        for _ in 0..iters {
            let az = a.matmul(&z);
            let t1 = eye.scale(7.0).sub(&az);
            let t2 = eye.scale(15.0).sub(&az.matmul(&t1));
            let t3 = eye.scale(13.0).sub(&az.matmul(&t2));
            z = z.matmul(&t3).scale(0.25);
        }
        z
    }

    #[test]
    fn nystromformer_transpose_free_path_reproduces_materialised_output() {
        // The pre-refactor Nyströmformer pipeline, reconstructed with the
        // materialised-transpose hyperpower seed above, must match the
        // production transpose-free path bit-for-bit: the fused seed
        // computes the same single product per element.  (A hardcoded
        // output digest would be libm-specific; the reconstruction checks
        // the same equivalence on any platform.)
        let (q, k, v) = qkv(42, 64, 16);
        let d = 16;
        let got = approximate(Method::Nystromformer, &q, &k, &v, d, &mut Rng::new(13));

        let ctx = KernelCtx::global();
        let lq = segment_means(&q, d);
        let lk = segment_means(&k, d);
        let a = row_softmax(&kernels::matmul_transb(ctx, &lq, &lk));
        let f3 = row_softmax(&kernels::matmul_transb(ctx, &lq, &k));
        let z = hyperpower_pinv_materialised(&a, 10);
        let rest = z.matmul(&f3.matmul(&v));
        let s1 = kernels::matmul_transb(ctx, &q, &lk);
        let want = kernels::row_softmax_matmul(ctx, &s1, &rest);

        assert_eq!((got.rows, got.cols), (want.rows, want.cols));
        for (x, y) in got.data.iter().zip(&want.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn skyformer_output_is_bit_identical_across_pool_modes() {
        // the Skyformer path (scores -> Newton–Schulz -> PSD completion)
        // runs entirely on kernels under the determinism contract: the
        // same seeds must give the same bits in both pool backends
        use crate::kernels::pool;
        let (q, k, v) = qkv(42, 64, 16);
        let prior = pool::current_mode();
        pool::set_mode(pool::Mode::Scoped);
        let scoped = approximate(Method::Skyformer, &q, &k, &v, 16, &mut Rng::new(13));
        pool::set_mode(pool::Mode::Pinned);
        let pinned = approximate(Method::Skyformer, &q, &k, &v, 16, &mut Rng::new(13));
        pool::set_mode(prior);
        for (x, y) in scoped.data.iter().zip(&pinned.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn segment_means_preserve_global_mean() {
        let (q, _, _) = qkv(8, 37, 8);
        let sm = segment_means(&q, 5);
        assert_eq!(sm.rows, 5);
        // weighted mean of segment means == global mean (weights = seg sizes)
        let global: f32 = (0..q.rows).map(|i| q.row(i).iter().sum::<f32>()).sum::<f32>() / q.rows as f32;
        let sizes = [8.0f32, 8.0, 7.0, 7.0, 7.0];
        let weighted: f32 = (0..5)
            .map(|s| sm.row(s).iter().sum::<f32>() * sizes[s])
            .sum::<f32>()
            / 37.0;
        assert!((global - weighted).abs() < 1e-3);
    }
}
