//! Synthetic Q/K/V probes for the Figure-1 study.
//!
//! The paper embeds Wikitext-2 through initialized or pretrained BERT
//! weight matrices.  Substitution (DESIGN.md §5): what Figure 1 actually
//! depends on is the *spectral profile* of Q and K, so we generate two
//! regimes:
//!
//! * `Init` — i.i.d. Gaussian rows: the distribution of Q/K under a
//!   freshly initialized model (random W on near-isotropic embeddings).
//! * `Pretrained` — anisotropic rows: a low-rank "colored" spectrum
//!   (geometric singular-value decay) plus per-token norm dispersion, the
//!   profile reported for trained attention (Figure 4 of the paper and
//!   prior work on fast singular-value decay).

use crate::linalg::Matrix;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Regime {
    Init,
    Pretrained,
}

impl Regime {
    pub fn name(&self) -> &'static str {
        match self {
            Regime::Init => "init",
            Regime::Pretrained => "pretrained",
        }
    }
}

/// A (Q, K, V) probe, pre-scaled by p^{-1/4} on q/k like every consumer
/// expects.
pub struct Probe {
    pub q: Matrix,
    pub k: Matrix,
    pub v: Matrix,
}

/// Generate one probe of `n` tokens with head dim `p`.
pub fn probe(regime: Regime, n: usize, p: usize, rng: &mut Rng) -> Probe {
    let scale = (p as f32).powf(-0.25);
    match regime {
        Regime::Init => Probe {
            q: Matrix::randn(rng, n, p, scale),
            k: Matrix::randn(rng, n, p, scale),
            v: Matrix::randn(rng, n, p, 1.0),
        },
        Regime::Pretrained => {
            let q = colored(rng, n, p, scale);
            let k = colored(rng, n, p, scale);
            Probe {
                q,
                k,
                v: colored(rng, n, p, 1.0),
            }
        }
    }
}

/// Anisotropic matrix: G @ diag(decay) @ R with geometric decay 0.85^j and
/// lognormal per-row norm dispersion — matches the fast singular-value
/// decay / token-norm spread of trained BERT projections.
fn colored(rng: &mut Rng, n: usize, p: usize, scale: f32) -> Matrix {
    let g = Matrix::randn(rng, n, p, 1.0);
    let mut rot = Matrix::randn(rng, p, p, 1.0 / (p as f32).sqrt());
    // decay spectrum
    for j in 0..p {
        let d = 0.85f32.powi(j as i32);
        for i in 0..p {
            rot[(i, j)] *= d;
        }
    }
    let mut out = g.matmul(&rot);
    for i in 0..n {
        // mild lognormal norm dispersion: enough anisotropy to change the
        // leverage-score profile, small enough that exp(q.k) on the lifted
        // SM kernel stays in f32 range (BERT activations are bounded too)
        let disp = (0.3 * rng.normal()).exp();
        for x in out.row_mut(i) {
            *x *= disp * scale * 1.3; // restore ~init mean row norm
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd::singular_values;

    #[test]
    fn shapes_and_finiteness() {
        let mut rng = Rng::new(0);
        for regime in [Regime::Init, Regime::Pretrained] {
            let pr = probe(regime, 64, 16, &mut rng);
            assert_eq!((pr.q.rows, pr.q.cols), (64, 16));
            assert!(pr.q.is_finite() && pr.k.is_finite() && pr.v.is_finite());
        }
    }

    #[test]
    fn pretrained_decays_faster_than_init() {
        let mut rng = Rng::new(1);
        let init = probe(Regime::Init, 128, 16, &mut rng);
        let pre = probe(Regime::Pretrained, 128, 16, &mut rng);
        let ratio = |m: &Matrix| {
            let sv = singular_values(m);
            sv[8] / sv[0] // tail-to-head singular value ratio
        };
        assert!(
            ratio(&pre.q) < ratio(&init.q) * 0.8,
            "pretrained q not anisotropic: {} vs {}",
            ratio(&pre.q),
            ratio(&init.q)
        );
    }

    #[test]
    fn deterministic_given_rng() {
        let a = probe(Regime::Init, 16, 8, &mut Rng::new(7));
        let b = probe(Regime::Init, 16, 8, &mut Rng::new(7));
        assert_eq!(a.q, b.q);
    }
}
