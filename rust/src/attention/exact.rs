//! Exact (quadratic) attention outputs: the targets of the Figure-1 study.
//!
//! The quadratic paths run on the fused kernels: `q k^T` via
//! `matmul_transb` (no materialised transpose) and `softmax(S) V` via
//! `row_softmax_matmul` (no materialised row-stochastic matrix).

use crate::kernels::{self, KernelCtx};
use crate::linalg::Matrix;
use crate::nystrom::{kernel_matrix, Kernel};

/// Row-stochastic softmax of a score matrix (stable).
pub fn row_softmax(s: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(s.rows, s.cols);
    for i in 0..s.rows {
        let row = s.row(i);
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
        let mut sum = 0.0f32;
        let orow = out.row_mut(i);
        for (o, &x) in orow.iter_mut().zip(row) {
            *o = (x - max).exp();
            sum += *o;
        }
        let inv = 1.0 / sum.max(1e-30);
        for o in orow {
            *o *= inv;
        }
    }
    out
}

/// Vanilla self-attention `softmax(q k^T) v` on pre-scaled q/k — the
/// score matrix is the only n x m intermediate (fused softmax·V).
pub fn softmax_attention(q: &Matrix, k: &Matrix, v: &Matrix) -> Matrix {
    softmax_attention_in(KernelCtx::global(), q, k, v)
}

/// [`softmax_attention`] under an explicit kernel context — the
/// per-request reference path the serving layer's batched dispatch is
/// bit-compared against (tests/serve.rs).
pub fn softmax_attention_in(ctx: KernelCtx, q: &Matrix, k: &Matrix, v: &Matrix) -> Matrix {
    let s = kernels::matmul_transb(ctx, q, k);
    kernels::row_softmax_matmul(ctx, &s, v)
}

/// Kernelized Attention (paper Eq. 3): `kappa(q, k) v`, no normalisation.
pub fn kernelized_attention(q: &Matrix, k: &Matrix, v: &Matrix) -> Matrix {
    kernel_matrix(Kernel::Gaussian, q, k).matmul(v)
}

/// [`kernelized_attention`] under an explicit kernel context.  Same
/// composition (`gaussian_scores` then `matmul`), so it is bit-identical
/// to the global-ctx path for any thread count by the kernel
/// determinism contract.
pub fn kernelized_attention_in(ctx: KernelCtx, q: &Matrix, k: &Matrix, v: &Matrix) -> Matrix {
    let s = kernels::gaussian_scores(ctx, q, k);
    kernels::matmul(ctx, &s, v)
}

/// The un-normalised softmax score matrix `A = exp(q k^T)` (pre-scaled).
pub fn unnormalized_scores(q: &Matrix, k: &Matrix) -> Matrix {
    kernel_matrix(Kernel::Softmax, q, k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::new(0);
        let q = Matrix::randn(&mut rng, 12, 8, 0.5);
        let k = Matrix::randn(&mut rng, 10, 8, 0.5);
        let w = row_softmax(&q.matmul(&k.transpose()));
        for i in 0..12 {
            let s: f32 = w.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn softmax_attention_of_constant_v() {
        let mut rng = Rng::new(1);
        let q = Matrix::randn(&mut rng, 9, 8, 0.5);
        let k = Matrix::randn(&mut rng, 7, 8, 0.5);
        let v = Matrix::from_fn(7, 3, |_, j| j as f32);
        let out = softmax_attention(&q, &k, &v);
        for i in 0..9 {
            for j in 0..3 {
                assert!((out[(i, j)] - j as f32).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn kernelized_single_token_identity() {
        let q = Matrix::from_rows(vec![vec![0.3f32; 8]]);
        let v = Matrix::from_rows(vec![(0..5).map(|x| x as f32).collect()]);
        let out = kernelized_attention(&q, &q, &v);
        for j in 0..5 {
            assert!((out[(0, j)] - j as f32).abs() < 1e-5);
        }
    }
}
