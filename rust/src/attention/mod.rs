//! Native-rust reference implementations of all nine attention mechanisms
//! (Table 1's model column) on the dense substrate.
//!
//! These power the Figure-1 matrix-approximation study exactly as the paper
//! runs it: every method approximates the output of vanilla softmax
//! self-attention `D^{-1} A V` on the same (Q, K, V), and the error is the
//! spectral norm of the output difference.  They also serve as
//! cross-checks of the HLO-side numerics.
//!
//! Convention: all functions take **pre-scaled** q, k (multiplied by
//! p^{-1/4}; see `python/compile/kernels/ref.py` for why this folds both
//! the softmax 1/sqrt(p) and the Gaussian bandwidth).

pub mod approximators;
pub mod exact;
pub mod probes;

pub use approximators::{approximate, Method, METHODS};
