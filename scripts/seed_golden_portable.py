#!/usr/bin/env python3
"""Seed rust/tests/golden/kernels.portable.digest without a Rust toolchain.

Bit-exact emulation of `skyformer kernels --digest --suite portable`
(= `kernels::digest_suite_portable(ctx, 96, 42)`): the portable suite is
restricted to kernels whose data path is pure IEEE-754 f32 `+`/`*` in a
fixed reduction order (KERNELS.md) — matmul, matmul_transa,
matmul_transb, scale_add — on Uniform[-1,1) inputs whose generation is
pure bit manipulation.  Every one of those operations rounds identically
on any IEEE platform, so numpy float32 (which performs exactly one
rounding per elementwise op and is never allowed to use FMA here)
reproduces the Rust outputs bit-for-bit, and the digests below are the
digests the binary will print.

Emulated, op for op:
  * util::rng::Rng (SplitMix64): uniform() = (next_u64() >> 40) / 2^24 —
    a 24-bit integer scaled by a power of two, both steps exact;
    range_f32(-1, 1) = -1.0 + u * 2.0 — again exact (multiples of 2^-23
    in [-1, 1) are representable).
  * kernels::ops::matmul / matmul_transa: per-element strictly
    increasing-k accumulation (k-panelling never reorders a single
    element's reduction), one f32 mul + one f32 add per step.
  * kernels::ops::matmul_transb: tile::dot's fixed lane order — LANES=8
    accumulators sweep full blocks in increasing block order, lanes
    combine in increasing-lane order (seeded from 0.0), no tail at
    n = 96.
  * kernels::ops::scale_add: fl(fl(alpha*a) + fl(beta*b)) per element.
  * kernels::digest: order-sensitive FNV-1a over rows, cols, and each
    f32's zero-extended bit pattern.

The fixture is written with a `# seeded-by: emulation` provenance
header: rust/tests/golden.rs treats an emulation-seeded fixture as a
warn-only check under plain `cargo test` (tier-1 stays safe even if
this emulation were wrong) while scripts/ci.sh hard-fails on any
mismatch.  Reseeding on a toolchain host (SKYFORMER_GOLDEN_SEED=1)
upgrades the header to `# seeded-by: host`, which cargo test then
hard-asserts.

Usage: python3 scripts/seed_golden_portable.py [--check]
  --check  verify the committed fixture instead of rewriting it
"""

import sys
from pathlib import Path

import numpy as np

MASK = (1 << 64) - 1
GOLDEN = 0x9E3779B97F4A7C15
N = 96
SEED = 42
LANES = 8
FIXTURE = Path(__file__).resolve().parent.parent / "rust/tests/golden/kernels.portable.digest"
HEADER = "# seeded-by: emulation (scripts/seed_golden_portable.py)"

f32 = np.float32


class Rng:
    """util::rng::Rng — SplitMix64 with the avalanche-seeded constructor."""

    def __init__(self, seed):
        self.state = (seed ^ GOLDEN) & MASK

    def next_u64(self):
        self.state = (self.state + GOLDEN) & MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
        return z ^ (z >> 31)

    def uniform(self):
        # (next_u64() >> 40) as f32 / (1 << 24) as f32 — both exact
        return f32(self.next_u64() >> 40) / f32(1 << 24)

    def range_f32(self, lo, hi):
        return f32(lo) + self.uniform() * (f32(hi) - f32(lo))


def rand_uniform(rng, rows, cols, lo, hi):
    """Matrix::rand_uniform — from_fn row-major fill order."""
    data = np.empty((rows, cols), dtype=f32)
    for i in range(rows):
        for j in range(cols):
            data[i, j] = rng.range_f32(lo, hi)
    return data


def matmul(a, b):
    """ops::matmul — per element: increasing-k, one rounded mul + add per step."""
    m, k = a.shape
    _, n = b.shape
    c = np.zeros((m, n), dtype=f32)
    for kx in range(k):
        c += a[:, kx : kx + 1] * b[kx : kx + 1, :]
    return c


def matmul_transa(a, b):
    """ops::matmul_transa — out[i,j] = sum_r a[r,i]*b[r,j], increasing r."""
    k, m = a.shape
    _, n = b.shape
    c = np.zeros((m, n), dtype=f32)
    for r in range(k):
        c += a[r, :][:, None] * b[r, :][None, :]
    return c


def matmul_transb(a, b):
    """ops::matmul_transb — out[i,j] = tile::dot(a.row(i), b.row(j))."""
    m, k = a.shape
    n = b.shape[0]
    blocks = k // LANES
    acc = np.zeros((m, n, LANES), dtype=f32)
    for c in range(blocks):
        lo = c * LANES
        acc += a[:, None, lo : lo + LANES] * b[None, :, lo : lo + LANES]
    total = np.zeros((m, n), dtype=f32)
    for l in range(LANES):
        total = total + acc[:, :, l]
    for t in range(blocks * LANES, k):  # tail (empty at k=96)
        total = total + a[:, t][:, None] * b[:, t][None, :]
    return total


def scale_add(a, alpha, b, beta):
    """ops::scale_add — fl(fl(alpha*a) + fl(beta*b)) per element."""
    return f32(alpha) * a + f32(beta) * b


def digest(mat):
    """kernels::digest — order-sensitive FNV-1a over shape then bits."""
    h = 0xCBF29CE484222325
    prime = 0x100000001B3
    rows, cols = mat.shape
    h = ((h ^ rows) * prime) & MASK
    h = ((h ^ cols) * prime) & MASK
    bits = np.ascontiguousarray(mat, dtype="<f4").view("<u4").reshape(-1)
    for x in bits:
        h = ((h ^ int(x)) * prime) & MASK
    return h


def suite_lines():
    rng = Rng(SEED)
    a = rand_uniform(rng, N, N, -1.0, 1.0)
    b = rand_uniform(rng, N, N, -1.0, 1.0)

    # internal self-checks: the emulation must be consistent with itself
    # in the same ways the Rust kernels are consistent with their oracles
    assert a.min() >= -1.0 and a.max() < 1.0, "rand_uniform out of range"
    ta = matmul(np.ascontiguousarray(a.T), b)
    ta2 = matmul_transa(a, b)
    assert (ta.view("<u4") == ta2.view("<u4")).all(), "transa emulation inconsistent"
    one = np.eye(N, dtype=f32)
    assert (matmul(a, one).view("<u4") == a.view("<u4")).all(), "matmul identity failed"

    outs = [
        ("matmul", matmul(a, b)),
        ("matmul_transa", matmul_transa(a, b)),
        ("matmul_transb", matmul_transb(a, b)),
        ("scale_add", scale_add(a, 7.0, b, -1.0)),
    ]
    return [f"{name} {digest(m):016x}" for name, m in outs]


def main():
    lines = suite_lines()
    body = HEADER + "\n" + "\n".join(lines) + "\n"
    if "--check" in sys.argv[1:]:
        current = FIXTURE.read_text()
        got = [l for l in current.splitlines() if not l.startswith("#")]
        want = [l for l in body.splitlines() if not l.startswith("#")]
        if got != want:
            print("portable fixture digests DIFFER from emulation:", file=sys.stderr)
            print("  fixture :", got, file=sys.stderr)
            print("  emulated:", want, file=sys.stderr)
            sys.exit(1)
        print(f"portable fixture OK ({FIXTURE})")
        return
    FIXTURE.parent.mkdir(parents=True, exist_ok=True)
    FIXTURE.write_text(body)
    print(f"seeded {FIXTURE}:")
    print(body, end="")


if __name__ == "__main__":
    main()
