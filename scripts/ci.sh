#!/usr/bin/env bash
# Offline CI gate: format, lint, build, test — all without the `pjrt`
# feature so nothing needs a PJRT plugin or network access.  Run from the
# repo root:  scripts/ci.sh
#
# Pass `--pjrt` to additionally build the PJRT-backed paths (requires the
# real xla crate to resolve; the default offline build uses the vendored
# stub in rust/xla-stub).
set -euo pipefail
cd "$(dirname "$0")/.."

WITH_PJRT=0
for arg in "$@"; do
    case "$arg" in
        --pjrt) WITH_PJRT=1 ;;
        *) echo "unknown flag: $arg" >&2; exit 2 ;;
    esac
done

echo "==> cargo fmt --check"
cargo fmt --all -- --check

echo "==> cargo clippy (offline feature set, warnings are errors)"
cargo clippy --workspace --no-default-features --all-targets -- -D warnings

echo "==> cargo build (offline feature set)"
cargo build --workspace --release

echo "==> cargo test (offline feature set, SKYFORMER_THREADS=1)"
SKYFORMER_THREADS=1 cargo test --workspace --release -q

echo "==> cargo test (offline feature set, SKYFORMER_THREADS=4)"
SKYFORMER_THREADS=4 cargo test --workspace --release -q

echo "==> kernel determinism: digests must match across thread counts"
DIG1=$(target/release/skyformer kernels --digest --threads 1)
DIG4=$(target/release/skyformer kernels --digest --threads 4)
if [ "$DIG1" != "$DIG4" ]; then
    echo "kernel digests diverged between --threads 1 and --threads 4:" >&2
    diff <(echo "$DIG1") <(echo "$DIG4") >&2 || true
    exit 1
fi
echo "    $(echo "$DIG1" | wc -l | tr -d ' ') kernels bit-identical"

echo "==> offline benches smoke-run (bench artifact + obs dump path)"
cargo bench --bench table2_time -- --out /tmp/BENCH_table2.json
test -s /tmp/BENCH_table2.json

if [ "$WITH_PJRT" = 1 ]; then
    echo "==> cargo build --features pjrt"
    cargo build --workspace --release --features pjrt
    echo "==> cargo test --features pjrt"
    cargo test --workspace --release --features pjrt -q
fi

echo "CI OK"
