#!/usr/bin/env bash
# Offline CI gate: format, lint, build, test — all without the `pjrt`
# feature so nothing needs a PJRT plugin or network access.  Run from the
# repo root:  scripts/ci.sh
#
# Pass `--pjrt` to additionally build the PJRT-backed paths (requires the
# real xla crate to resolve; the default offline build uses the vendored
# stub in rust/xla-stub).
set -euo pipefail
cd "$(dirname "$0")/.."

WITH_PJRT=0
for arg in "$@"; do
    case "$arg" in
        --pjrt) WITH_PJRT=1 ;;
        *) echo "unknown flag: $arg" >&2; exit 2 ;;
    esac
done

echo "==> cargo fmt --check"
cargo fmt --all -- --check

echo "==> cargo clippy (offline feature set, warnings are errors)"
cargo clippy --workspace --no-default-features --all-targets -- -D warnings

echo "==> cargo build (offline feature set)"
cargo build --workspace --release

echo "==> cargo test (offline feature set, SKYFORMER_THREADS=1, scoped pool)"
SKYFORMER_THREADS=1 SKYFORMER_POOL=scoped cargo test --workspace --release -q

echo "==> cargo test (offline feature set, SKYFORMER_THREADS=4, pinned pool)"
SKYFORMER_THREADS=4 SKYFORMER_POOL=pinned cargo test --workspace --release -q

echo "==> kernel determinism: digest cross-check, threads {1,4,8} x pool {scoped,pinned}"
FIXTURE=rust/tests/golden/kernels.digest
# An UNSEEDED fixture means the numeric-drift gate is not enforcing:
# fail loudly instead of seeding in place (seeding is an explicit,
# one-time operator action — see KERNELS.md "Golden digest fixture").
if grep -q '^UNSEEDED' "$FIXTURE"; then
    echo "error: $FIXTURE is UNSEEDED; seed it on the CI platform with" >&2
    echo "  SKYFORMER_GOLDEN_SEED=1 cargo test --test golden" >&2
    echo "and commit the regenerated file." >&2
    exit 1
fi
WANT=$(cat "$FIXTURE")
for t in 1 4 8; do
    for m in scoped pinned; do
        DIG=$(SKYFORMER_POOL=$m target/release/skyformer kernels --digest --threads "$t")
        if [ "$DIG" != "$WANT" ]; then
            echo "kernel digests diverged from $FIXTURE at --threads $t, pool=$m:" >&2
            diff <(echo "$WANT") <(echo "$DIG") >&2 || true
            exit 1
        fi
    done
done
echo "    $(echo "$WANT" | wc -l | tr -d ' ') kernels bit-identical across 6 schedules + golden fixture"

echo "==> offline benches smoke-run (bench artifact + obs dump path)"
cargo bench --bench table2_time -- --out /tmp/BENCH_table2.json
test -s /tmp/BENCH_table2.json
cargo bench --bench coordinator_hotpath -- --out /tmp/BENCH_hotpath.json
test -s /tmp/BENCH_hotpath.json

if [ "$WITH_PJRT" = 1 ]; then
    echo "==> cargo build --features pjrt"
    cargo build --workspace --release --features pjrt
    echo "==> cargo test --features pjrt"
    cargo test --workspace --release --features pjrt -q
fi

echo "CI OK"
