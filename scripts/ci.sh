#!/usr/bin/env bash
# Offline CI gate: format, lint, build, test — all without the `pjrt`
# feature so nothing needs a PJRT plugin or network access.  Run from the
# repo root:  scripts/ci.sh
#
# Pass `--pjrt` to additionally build the PJRT-backed paths (requires the
# real xla crate to resolve; the default offline build uses the vendored
# stub in rust/xla-stub).
set -euo pipefail
cd "$(dirname "$0")/.."

WITH_PJRT=0
for arg in "$@"; do
    case "$arg" in
        --pjrt) WITH_PJRT=1 ;;
        *) echo "unknown flag: $arg" >&2; exit 2 ;;
    esac
done

echo "==> cargo fmt --check"
cargo fmt --all -- --check

echo "==> cargo clippy (offline feature set, warnings are errors)"
cargo clippy --workspace --no-default-features --all-targets -- -D warnings

echo "==> cargo build (offline feature set)"
cargo build --workspace --release

echo "==> cargo test (offline feature set, SKYFORMER_THREADS=1, scoped pool)"
SKYFORMER_THREADS=1 SKYFORMER_POOL=scoped cargo test --workspace --release -q

echo "==> cargo test (offline feature set, SKYFORMER_THREADS=4, pinned pool)"
SKYFORMER_THREADS=4 SKYFORMER_POOL=pinned cargo test --workspace --release -q

echo "==> portable kernel digests: cross-schedule + fixture gate (always enforcing)"
PORTABLE_FIXTURE=rust/tests/golden/kernels.portable.digest
# The portable suite is libm-free, so its committed digests hold on any
# IEEE-754 platform — this gate hard-fails on mismatch regardless of the
# fixture's seeded-by provenance (cargo test is warn-only for
# emulation-seeded fixtures; the enforcement lives here).
PWANT=$(grep -v '^#' "$PORTABLE_FIXTURE")
for t in 1 4 8; do
    for m in scoped pinned; do
        DIG=$(SKYFORMER_POOL=$m target/release/skyformer kernels --digest --suite portable --threads "$t")
        if [ "$DIG" != "$PWANT" ]; then
            echo "portable digests diverged from $PORTABLE_FIXTURE at --threads $t, pool=$m:" >&2
            diff <(echo "$PWANT") <(echo "$DIG") >&2 || true
            exit 1
        fi
    done
done
if python3 -c 'import numpy' 2>/dev/null; then
    python3 scripts/seed_golden_portable.py --check
else
    echo "    (numpy unavailable: skipped the off-host emulation cross-check)"
fi
echo "    $(echo "$PWANT" | wc -l | tr -d ' ') portable kernels bit-identical across 6 schedules + fixture"

echo "==> kernel determinism: digest cross-check, threads {1,4,8} x pool {scoped,pinned}"
FIXTURE=rust/tests/golden/kernels.digest
# An UNSEEDED fixture means the numeric-drift gate is not enforcing:
# fail loudly instead of seeding in place (seeding is an explicit,
# one-time operator action — see KERNELS.md "Golden digest fixture").
if grep -q '^UNSEEDED' "$FIXTURE"; then
    echo "error: $FIXTURE is UNSEEDED; seed it on the CI platform with" >&2
    echo "  SKYFORMER_GOLDEN_SEED=1 cargo test --test golden" >&2
    echo "and commit the regenerated file." >&2
    exit 1
fi
WANT=$(cat "$FIXTURE")
for t in 1 4 8; do
    for m in scoped pinned; do
        DIG=$(SKYFORMER_POOL=$m target/release/skyformer kernels --digest --threads "$t")
        if [ "$DIG" != "$WANT" ]; then
            echo "kernel digests diverged from $FIXTURE at --threads $t, pool=$m:" >&2
            diff <(echo "$WANT") <(echo "$DIG") >&2 || true
            exit 1
        fi
    done
done
echo "    $(echo "$WANT" | wc -l | tr -d ' ') kernels bit-identical across 6 schedules + golden fixture"

echo "==> serve-bench smoke: zero lost requests + batched-dispatch digest, both pool backends"
# --smoke: fixed seed, no deadlines, retry on backpressure, recomputes
# every completed request unbatched and asserts bitwise equality, and
# prints a `serve_digest <hex>` line folded over per-request output
# digests in id order — so it must be byte-identical across thread
# counts and pool backends no matter what batches the timing produced.
# Sharding and priority lanes change scheduling, never bytes: the digest
# must also be identical across --dispatchers {1,4}, and a 25% High-lane
# mix on the sharded runs must not move it either.
SERVE_REF=""
SERVE_SCHEDULES=0
for t in 1 4; do
    for m in scoped pinned; do
        for d in 1 4; do
            MIX=0
            if [ "$d" = 4 ]; then MIX=25; fi
            OUT=/tmp/BENCH_serve_${t}_${m}_${d}.json
            LINE=$(SKYFORMER_POOL=$m target/release/skyformer serve-bench --smoke \
                --requests 200 --clients 4 --seq 32,48 --dim 16 --threads "$t" \
                --dispatchers "$d" --priority-mix "$MIX" \
                --out "$OUT" | grep '^serve_digest ')
            test -s "$OUT"
            SERVE_SCHEDULES=$((SERVE_SCHEDULES + 1))
            if [ -z "$SERVE_REF" ]; then
                SERVE_REF="$LINE"
            elif [ "$LINE" != "$SERVE_REF" ]; then
                echo "serve digest diverged at --threads $t, pool=$m, --dispatchers $d:" >&2
                echo "  want: $SERVE_REF" >&2
                echo "  got:  $LINE" >&2
                exit 1
            fi
        done
    done
done
echo "    200-request smoke load: zero lost requests, $SERVE_REF stable across $SERVE_SCHEDULES schedules"

echo "==> serve stress gate: 16 clients x mixed lanes x shutdown races, both pool backends"
# 10 iterations per backend here (default is 3 under plain cargo test;
# the PR acceptance bar is 50 clean iterations, run manually via
# SKYFORMER_STRESS_ITERS=50).  Zero lost tickets, zero Dropped, every
# completed output bit-identical to the unbatched recompute.
for m in scoped pinned; do
    SKYFORMER_STRESS_ITERS=10 SKYFORMER_POOL=$m \
        cargo test --workspace --release -q --test serve_stress
done
echo "    stress suite clean: 10 iterations x {scoped, pinned}"

echo "==> offline benches smoke-run (bench artifact + obs dump path)"
cargo bench --bench table2_time -- --out /tmp/BENCH_table2.json
test -s /tmp/BENCH_table2.json
cargo bench --bench coordinator_hotpath -- --out /tmp/BENCH_hotpath.json
test -s /tmp/BENCH_hotpath.json
cargo bench --bench serve_dispatch -- --budget-ms 80 --out /tmp/BENCH_serve_dispatch.json
test -s /tmp/BENCH_serve_dispatch.json

if [ "$WITH_PJRT" = 1 ]; then
    echo "==> cargo build --features pjrt"
    cargo build --workspace --release --features pjrt
    echo "==> cargo test --features pjrt"
    cargo test --workspace --release --features pjrt -q
fi

echo "CI OK"
