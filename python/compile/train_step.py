"""Train / eval / embed step functions — the units that get AOT-lowered.

Each returned function is pure and jit-able:

* ``train_step(params, opt, tokens, labels, seed, lr)``
  -> ``(params', opt', loss, acc)`` — fwd + bwd + Adam, one HLO module.
* ``eval_step(params, tokens, labels, seed)`` -> ``(loss, acc)``
* ``embed_step(params, tokens, seed)`` -> pooled features (Table 3's f(x, W))
* ``init_step(seed)`` -> ``(params, opt)`` — so the rust coordinator can
  re-initialise for seed sweeps without touching python.

``seed`` is a uint32 scalar input; the PRNG key is derived in-graph.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import model, optimizer
from .configs import ModelConfig, TaskConfig


def _xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def _accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


def make_fns(task: TaskConfig, cfg: ModelConfig) -> dict:
    def loss_fn(params, tokens, labels, key):
        logits = model.forward(params, tokens, key, task, cfg)
        loss = _xent(logits, labels)
        return loss, _accuracy(logits, labels)

    def train_step(params, opt, tokens, labels, seed, lr):
        key = jax.random.PRNGKey(seed)
        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, tokens, labels, key
        )
        params, opt = optimizer.update(grads, opt, params, lr)
        return params, opt, loss, acc

    def eval_step(params, tokens, labels, seed):
        key = jax.random.PRNGKey(seed)
        return loss_fn(params, tokens, labels, key)

    def embed_step(params, tokens, seed):
        key = jax.random.PRNGKey(seed)
        if task.dual:
            k1, k2 = jax.random.split(key)
            e1 = model.encode(params, tokens[:, 0], k1, cfg)
            e2 = model.encode(params, tokens[:, 1], k2, cfg)
            return jnp.concatenate([e1, e2], axis=-1)
        return model.encode(params, tokens, key, cfg)

    def init_step(seed):
        key = jax.random.PRNGKey(seed)
        params = model.init_params(key, task, cfg)
        return params, optimizer.init(params)

    return {
        "train": train_step,
        "eval": eval_step,
        "embed": embed_step,
        "init": init_step,
    }
