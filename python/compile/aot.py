"""AOT lowering: JAX step functions -> HLO *text* artifacts + manifest.

This is the only place python touches the pipeline; ``make artifacts`` runs
it once and the rust coordinator is self-contained afterwards.

Interchange is HLO **text**, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the crate's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids
(see /opt/xla-example/README.md).

Every artifact is a *flat* function — pytrees are flattened here and the
leaf order/naming/shapes are recorded in ``manifest.json`` so the rust side
(runtime::manifest) can address parameters by name for checkpointing and
feed inputs positionally for execution.

Usage:
    python -m compile.aot --out-dir ../artifacts --set default
    python -m compile.aot --out-dir ../artifacts --tasks listops --attentions skyformer --pallas
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, model, train_step

_DTYPE_NAMES = {
    jnp.float32.dtype: "f32",
    jnp.int32.dtype: "i32",
    jnp.uint32.dtype: "u32",
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _leaf_specs(prefix: str, tree) -> list[dict]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        name = prefix + jax.tree_util.keystr(path)
        out.append(
            {
                "name": name,
                "shape": list(leaf.shape),
                "dtype": _DTYPE_NAMES[jnp.dtype(leaf.dtype)],
            }
        )
    return out


def _scalar(name: str, dtype: str) -> dict:
    return {"name": name, "shape": [], "dtype": dtype}


def _array(name: str, shape: tuple[int, ...], dtype: str) -> dict:
    return {"name": name, "shape": list(shape), "dtype": dtype}


def _spec_struct(entries: list[dict]):
    inv = {"f32": jnp.float32, "i32": jnp.int32, "u32": jnp.uint32}
    return [jax.ShapeDtypeStruct(tuple(e["shape"]), inv[e["dtype"]]) for e in entries]


def lower_config(
    task_name: str,
    attention: str,
    out_dir: Path,
    *,
    pallas: bool = False,
    kinds: tuple[str, ...] = ("init", "train", "eval", "embed"),
    num_features: int | None = None,
) -> list[dict]:
    """Lower all step functions for one (task, attention) config."""
    task = configs.TASKS[task_name]
    overrides = {"pallas": pallas}
    if num_features is not None:
        overrides["num_features"] = num_features
    cfg = configs.model_for(attention, **overrides)
    fns = train_step.make_fns(task, cfg)

    # Abstract params/opt to derive leaf specs without allocating real arrays.
    params_shape = jax.eval_shape(
        lambda s: model.init_params(jax.random.PRNGKey(s), task, cfg),
        jnp.uint32(0),
    )
    params_treedef = jax.tree_util.tree_structure(params_shape)
    opt_shape = {
        "m": params_shape,
        "v": params_shape,
        "t": jax.ShapeDtypeStruct((), jnp.float32),
    }
    opt_treedef = jax.tree_util.tree_structure(opt_shape)

    p_specs = _leaf_specs("params", params_shape)
    o_specs = _leaf_specs("opt", opt_shape)
    n_p, n_o = len(p_specs), len(o_specs)
    tok_shape = model.token_shape(task)
    lbl_shape = (task.batch_size,)

    stem = f"{task_name}_{attention}" + ("_pallas" if pallas else "")
    if num_features is not None:
        stem += f"_d{num_features}"
    entries = []

    def unflatten(leaves_p, leaves_o):
        return (
            jax.tree_util.tree_unflatten(params_treedef, leaves_p),
            jax.tree_util.tree_unflatten(opt_treedef, leaves_o),
        )

    def emit(kind: str, flat_fn, in_specs: list[dict], out_specs: list[dict]):
        t0 = time.time()
        # keep_unused: the positional feeding contract requires every leaf
        # to stay an entry parameter even if a kind (e.g. embed) ignores it.
        lowered = jax.jit(flat_fn, keep_unused=True).lower(*_spec_struct(in_specs))
        text = to_hlo_text(lowered)
        fname = f"{stem}.{kind}.hlo.txt"
        (out_dir / fname).write_text(text)
        entries.append(
            {
                "name": f"{stem}.{kind}",
                "file": fname,
                "kind": kind,
                "task": task_name,
                "attention": attention,
                "pallas": pallas,
                "inputs": in_specs,
                "outputs": out_specs,
                "num_params": n_p,
                "num_opt": n_o,
                "task_config": dataclasses.asdict(task),
                "model_config": dataclasses.asdict(cfg),
            }
        )
        print(f"  {fname}: {len(text)/1e6:.2f} MB in {time.time()-t0:.1f}s")

    if "init" in kinds:
        def init_flat(seed):
            params, opt = fns["init"](seed)
            return tuple(jax.tree_util.tree_leaves(params)) + tuple(
                jax.tree_util.tree_leaves(opt)
            )

        emit("init", init_flat, [_scalar("seed", "u32")], p_specs + o_specs)

    if "train" in kinds:
        def train_flat(*args):
            leaves_p = list(args[:n_p])
            leaves_o = list(args[n_p : n_p + n_o])
            tokens, labels, seed, lr = args[n_p + n_o :]
            params, opt = unflatten(leaves_p, leaves_o)
            params, opt, loss, acc = fns["train"](params, opt, tokens, labels, seed, lr)
            return (
                tuple(jax.tree_util.tree_leaves(params))
                + tuple(jax.tree_util.tree_leaves(opt))
                + (loss, acc)
            )

        in_specs = (
            p_specs
            + o_specs
            + [
                _array("tokens", tok_shape, "i32"),
                _array("labels", lbl_shape, "i32"),
                _scalar("seed", "u32"),
                _scalar("lr", "f32"),
            ]
        )
        out_specs = p_specs + o_specs + [_scalar("loss", "f32"), _scalar("acc", "f32")]
        emit("train", train_flat, in_specs, out_specs)

    if "eval" in kinds:
        def eval_flat(*args):
            leaves_p = list(args[:n_p])
            tokens, labels, seed = args[n_p:]
            params = jax.tree_util.tree_unflatten(params_treedef, leaves_p)
            loss, acc = fns["eval"](params, tokens, labels, seed)
            return (loss, acc)

        emit(
            "eval",
            eval_flat,
            p_specs
            + [
                _array("tokens", tok_shape, "i32"),
                _array("labels", lbl_shape, "i32"),
                _scalar("seed", "u32"),
            ],
            [_scalar("loss", "f32"), _scalar("acc", "f32")],
        )

    if "embed" in kinds:
        def embed_flat(*args):
            leaves_p = list(args[:n_p])
            tokens, seed = args[n_p:]
            params = jax.tree_util.tree_unflatten(params_treedef, leaves_p)
            return (fns["embed"](params, tokens, seed),)

        emb_dim = cfg.emb_dim * (2 if task.dual else 1)
        emit(
            "embed",
            embed_flat,
            p_specs + [_array("tokens", tok_shape, "i32"), _scalar("seed", "u32")],
            [_array("embed", (task.batch_size, emb_dim), "f32")],
        )

    return entries


# Artifact sets. "default" is what `make artifacts` builds; "full" adds every
# attention on every task (Table 1/2 complete grid).
def _set_default() -> list[tuple[str, str, bool]]:
    out = [("listops", a, False) for a in configs.ATTENTION_KINDS]
    for t in ("text", "retrieval", "pathfinder", "image"):
        for a in ("softmax", "kernelized", "skyformer"):
            out.append((t, a, False))
    out.append(("listops", "skyformer", True))  # pallas-path proof artifact
    return out


def _set_full() -> list[tuple[str, str, bool]]:
    out = [(t, a, False) for t in configs.TASKS for a in configs.ATTENTION_KINDS]
    out.append(("listops", "skyformer", True))
    return out


def _set_smoke() -> list[tuple[str, str, bool]]:
    return [("listops", "skyformer", False), ("listops", "skyformer", True)]


SETS = {"default": _set_default, "full": _set_full, "smoke": _set_smoke}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--set", dest="set_name", default=None, choices=sorted(SETS))
    ap.add_argument("--tasks", nargs="*", default=None)
    ap.add_argument("--attentions", nargs="*", default=None)
    ap.add_argument("--pallas", action="store_true")
    ap.add_argument("--kinds", nargs="*", default=("init", "train", "eval", "embed"))
    ap.add_argument(
        "--num-features",
        type=int,
        default=None,
        help="override the feature/landmark budget (ablation artifacts; "
        "the stem gains a _dN suffix)",
    )
    args = ap.parse_args()

    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest_path = out_dir / "manifest.json"
    manifest = (
        json.loads(manifest_path.read_text()) if manifest_path.exists() else {"artifacts": {}}
    )

    if args.tasks or args.attentions:
        tasks = args.tasks or list(configs.TASKS)
        attns = args.attentions or list(configs.ATTENTION_KINDS)
        jobs = [(t, a, args.pallas) for t in tasks for a in attns]
    else:
        jobs = SETS[args.set_name or "default"]()

    for task_name, attention, pallas in jobs:
        stem = f"{task_name}_{attention}" + ("_pallas" if pallas else "")
        if args.num_features is not None:
            stem += f"_d{args.num_features}"
        done = all(
            f"{stem}.{k}" in manifest["artifacts"]
            and (out_dir / f"{stem}.{k}.hlo.txt").exists()
            for k in args.kinds
        )
        if done:
            print(f"{stem}: up to date")
            continue
        print(f"{stem}: lowering ...")
        for entry in lower_config(
            task_name,
            attention,
            out_dir,
            pallas=pallas,
            kinds=tuple(args.kinds),
            num_features=args.num_features,
        ):
            manifest["artifacts"][entry["name"]] = entry
        manifest_path.write_text(json.dumps(manifest, indent=1))

    manifest_path.write_text(json.dumps(manifest, indent=1))
    print(f"manifest: {manifest_path} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
