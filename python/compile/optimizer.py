"""Adam, in-graph (Layer 2).

The optimizer lives inside the train-step HLO so the rust coordinator only
round-trips opaque leaf tensors between steps — no optimizer math on the
request path.  Learning rate is a runtime scalar input (the L3 scheduler
owns the schedule).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init(params) -> dict:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {
        "m": zeros,
        "v": jax.tree_util.tree_map(jnp.zeros_like, params),
        "t": jnp.zeros((), jnp.float32),
    }


def update(
    grads,
    state: dict,
    params,
    lr: jax.Array,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
):
    """One Adam step with bias correction. Returns (params', state')."""
    t = state["t"] + 1.0
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    bc1 = 1.0 - b1**t
    bc2 = 1.0 - b2**t

    def leaf(p, m_, v_):
        return p - lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)

    new_params = jax.tree_util.tree_map(leaf, params, m, v)
    return new_params, {"m": m, "v": v, "t": t}
