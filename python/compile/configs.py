"""Task and model configurations.

Mirrors the paper's LRA protocol (§5 Implementation Details): a 2-layer
transformer with 64 embedding dim, 128 hidden dim, 2 attention heads and
mean pooling, the same model for every attention variant; only the attention
module is swapped.  Sequence lengths are the CPU-budget "LRA-lite" variants
recorded in DESIGN.md §5 — the rust coordinator (Layer 3) reads these via
``artifacts/manifest.json`` so the three layers can never disagree on shapes.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class TaskConfig:
    """One LRA task: shapes of the workload the rust data generators emit."""

    name: str
    seq_len: int
    vocab_size: int
    num_classes: int
    batch_size: int
    dual: bool = False  # Retrieval: two documents per example


@dataclass(frozen=True)
class ModelConfig:
    """The (fixed) LRA transformer + the pluggable attention settings."""

    attention: str = "skyformer"
    emb_dim: int = 64
    ffn_dim: int = 128
    num_heads: int = 2
    num_layers: int = 2
    # number of features / landmarks / projections / buckets — the paper
    # controls this to 128 across methods for comparable complexity.
    num_features: int = 128
    ns_iters: int = 6  # Newton–Schulz iterations (§4.4)
    gamma: float = 1e-3  # Lemma-3 ridge
    block_size: int = 32  # bigbird / reformer chunk block
    pallas: bool = False  # True: lower through the L1 Pallas kernels

    @property
    def head_dim(self) -> int:
        assert self.emb_dim % self.num_heads == 0
        return self.emb_dim // self.num_heads


# LRA-lite task suite (paper sequence lengths in comments).
TASKS: dict[str, TaskConfig] = {
    # ListOps: hierarchical ops over nested lists (paper: 2k tokens).
    "listops": TaskConfig("listops", seq_len=256, vocab_size=20, num_classes=10, batch_size=32),
    # Byte-level text classification (paper: IMDb, 4k bytes).
    "text": TaskConfig("text", seq_len=512, vocab_size=256, num_classes=2, batch_size=16),
    # Document retrieval, dual tower (paper: AAN, 2 x 4k bytes).
    "retrieval": TaskConfig(
        "retrieval", seq_len=256, vocab_size=256, num_classes=2, batch_size=16, dual=True
    ),
    # Pathfinder 32x32 (paper: 1024 pixels — kept exact).
    "pathfinder": TaskConfig("pathfinder", seq_len=1024, vocab_size=256, num_classes=2, batch_size=8),
    # Image classification on 32x32 grayscale (paper: CIFAR-10 — 1024 pixels).
    "image": TaskConfig("image", seq_len=1024, vocab_size=256, num_classes=10, batch_size=8),
}

ATTENTION_KINDS = (
    "softmax",
    "kernelized",
    "skyformer",
    "nystromformer",
    "linformer",
    "performer",
    "reformer",
    "informer",
    "bigbird",
)


def model_for(attention: str, **overrides) -> ModelConfig:
    if attention not in ATTENTION_KINDS:
        raise ValueError(f"unknown attention {attention!r}; expected one of {ATTENTION_KINDS}")
    return dataclasses.replace(ModelConfig(attention=attention), **overrides)
