"""Vanilla self-attention baseline (Vaswani et al. 2017): softmax(QK^T/sqrt(p))V.

The quadratic-cost reference every approximator in the paper is measured
against (Table 1 "Self-Attention" row).  Two lowerings: the L1 Pallas
online-softmax kernel (``cfg.pallas``) or the fused jnp oracle.
"""

from __future__ import annotations

import jax

from ..kernels import autodiff, ref
from . import common


def init(key, cfg, seq_len):  # noqa: ARG001 - uniform module signature
    return {}


def apply(extra, q, k, v, key, cfg):  # noqa: ARG001
    if cfg.pallas:
        def f(q2, k2, v2, _key):
            return autodiff.softmax_attention(q2, k2, v2)
    else:
        def f(q2, k2, v2, _key):
            return ref.softmax_attention(q2, k2, v2)
    return common.map_heads(f, q, k, v, key)
