"""Kernelized Attention (paper §4.1): the softmax structure replaced by a
Gaussian kernel, ``C V`` with ``C = kappa(Q/p^{1/4}, K/p^{1/4})``.

Still O(n^2) — this is the paper's *stability* contribution (Table 3);
Skyformer is its O(n d) Nyström acceleration.
"""

from __future__ import annotations

from ..kernels import autodiff, ref
from . import common


def init(key, cfg, seq_len):  # noqa: ARG001
    return {}


def apply(extra, q, k, v, key, cfg):  # noqa: ARG001
    if cfg.pallas:
        def f(q2, k2, v2, _key):
            return autodiff.kernelized_attention(q2, k2, v2)
    else:
        def f(q2, k2, v2, _key):
            return ref.kernelized_attention(q2, k2, v2)
    return common.map_heads(f, q, k, v, key)
