"""BigBird-style block-sparse attention baseline (Zaheer et al. 2020).

Block pattern per query block i: one global block (block 0), the sliding
window {i-1, i, i+1} (wrap-around), and r random blocks.  Queries inside the
global block additionally attend to the full sequence.  Duplicate gathered
blocks (e.g. the window of block 1 overlapping the global block) are masked
so no key is double-counted.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common

_N_RANDOM = 2


def init(key, cfg, seq_len):  # noqa: ARG001
    return {}


def apply(extra, q, k, v, key, cfg):
    b = cfg.block_size

    def f(q2, k2, v2, subkey):
        n, p = q2.shape
        d_v = v2.shape[1]
        bb = min(b, n)
        pad = (-n) % bb
        if pad:
            q2 = jnp.pad(q2, ((0, pad), (0, 0)))
            k2 = jnp.pad(k2, ((0, pad), (0, 0)))
            v2 = jnp.pad(v2, ((0, pad), (0, 0)))
        np_ = q2.shape[0]
        nb = np_ // bb
        blocks_i = jnp.arange(nb)
        rand = jax.random.randint(subkey, (nb, _N_RANDOM), 0, nb)
        sel = jnp.stack(
            [
                jnp.zeros(nb, jnp.int32),  # global block
                (blocks_i - 1) % nb,
                blocks_i,
                (blocks_i + 1) % nb,
            ],
            axis=1,
        )
        sel = jnp.concatenate([sel, rand], axis=1)  # (nb, s)
        s_sel = sel.shape[1]
        # mask duplicate block ids (keep first occurrence only)
        eq = sel[:, :, None] == sel[:, None, :]  # (nb, j, j')
        dup = jnp.sum(jnp.tril(eq, k=-1), axis=-1) > 0  # (nb, j)

        kb = k2.reshape(nb, bb, p)
        vb = v2.reshape(nb, bb, d_v)
        kg = kb[sel].reshape(nb, s_sel * bb, p)  # (nb, s*b, p)
        vg = vb[sel].reshape(nb, s_sel * bb, d_v)
        qb = q2.reshape(nb, bb, p)
        s = jnp.einsum("ncp,nmp->ncm", qb, kg)  # (nb, b, s*b)
        keymask = jnp.repeat(dup, bb, axis=1)  # (nb, s*b)
        # also mask padded key positions
        kpos = sel[:, :, None] * bb + jnp.arange(bb)[None, None, :]
        kpos = kpos.reshape(nb, s_sel * bb)
        s = jnp.where((keymask | (kpos >= n))[:, None, :], -1e30, s)
        w = common.row_softmax(s)
        out = jnp.einsum("ncm,nmd->ncd", w, vg).reshape(np_, d_v)

        # global block queries attend to everything
        kmask = (jnp.arange(np_) >= n)[None, :]
        sg = jnp.where(kmask, -1e30, q2[:bb] @ k2.T)
        og = common.row_softmax(sg) @ v2
        out = out.at[:bb].set(og)
        return out[:n]

    return common.map_heads(f, q, k, v, key)
