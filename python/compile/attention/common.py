"""Shared helpers for the attention module registry.

Every attention module exposes

* ``init(key, cfg, seq_len) -> dict`` — extra learnable params (most return {})
* ``apply(extra, q, k, v, key, cfg) -> out`` — q, k, v of shape (B, H, N, D);
  q and k arrive **pre-scaled by p^-1/4** so ``q @ k.T == QK^T/sqrt(p)`` and
  the Gaussian kernel has the paper's bandwidth.

Modules implement per-head 2D math; ``map_heads`` lifts it over (B, H) with
an independent PRNG key per head so stochastic approximators (skyformer
landmarks, performer features, reformer hashes, bigbird random blocks) do
not share randomness across heads.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def map_heads(
    fn: Callable[[jax.Array, jax.Array, jax.Array, jax.Array], jax.Array],
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    key: jax.Array,
) -> jax.Array:
    """vmap ``fn(q2d, k2d, v2d, key)`` over the flattened (B*H) leading dim."""
    b, h, n, d = q.shape
    m = k.shape[2]
    qf = q.reshape(b * h, n, d)
    kf = k.reshape(b * h, m, d)
    vf = v.reshape(b * h, m, v.shape[3])
    keys = jax.random.split(key, b * h)
    out = jax.vmap(fn)(qf, kf, vf, keys)
    return out.reshape(b, h, n, out.shape[-1])


def row_softmax(s: jax.Array) -> jax.Array:
    """Numerically stable row softmax."""
    s = s - jnp.max(s, axis=-1, keepdims=True)
    w = jnp.exp(s)
    return w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-30)
