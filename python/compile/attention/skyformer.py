"""Skyformer (paper §4.2): modified Nyström approximation of Kernelized
Attention.

Per head: sample ``d = cfg.num_features`` landmark rows uniformly from the
lifted design matrix [Q; K] (Definition 1, without replacement — DESIGN.md
§6), then

    out = kappa(Q, L) (kappa(L, L) + gamma I)^{-1} kappa(L, K) V

with the inverse computed by the Lemma-3-preconditioned Newton–Schulz
iteration.  O(n d p + d^3) per head.
"""

from __future__ import annotations

import jax

from ..kernels import autodiff, ref
from . import common


def init(key, cfg, seq_len):  # noqa: ARG001
    return {}


def apply(extra, q, k, v, key, cfg):  # noqa: ARG001
    d_features = cfg.num_features

    def f(q2, k2, v2, subkey):
        two_n = q2.shape[0] + k2.shape[0]
        lmk = ref.uniform_landmarks(subkey, two_n, min(d_features, two_n))
        if cfg.pallas:
            return autodiff.skyformer_attention(
                q2, k2, v2, lmk, cfg.gamma, cfg.ns_iters
            )
        return ref.skyformer_attention(
            q2, k2, v2, lmk, gamma=cfg.gamma, iters=cfg.ns_iters
        )

    return common.map_heads(f, q, k, v, key)
