"""Attention module registry: the 9 mechanisms of the paper's Table 1.

Each module exposes ``init(key, cfg, seq_len) -> dict`` and
``apply(extra, q, k, v, key, cfg) -> out`` (see common.py for the contract).
"""

from __future__ import annotations

from . import (  # noqa: F401
    bigbird,
    common,
    informer,
    kernelized,
    linformer,
    nystromformer,
    performer,
    reformer,
    skyformer,
    softmax,
)

REGISTRY = {
    "softmax": softmax,
    "kernelized": kernelized,
    "skyformer": skyformer,
    "nystromformer": nystromformer,
    "linformer": linformer,
    "performer": performer,
    "reformer": reformer,
    "informer": informer,
    "bigbird": bigbird,
}


def get(name: str):
    try:
        return REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown attention {name!r}; have {sorted(REGISTRY)}") from None
