"""Informer ProbSparse attention baseline (Zhou et al. 2020).

Queries are scored by the sparsity measure ``M(q) = max_j(q.k_j) -
mean_j(q.k_j)`` estimated on a random key sample; only the top-u queries run
full attention, the rest emit the mean of V (the non-causal Informer
fallback).  u and the key-sample size are both ``cfg.num_features`` to match
the paper's per-row visit budget.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common


def init(key, cfg, seq_len):  # noqa: ARG001
    return {}


def apply(extra, q, k, v, key, cfg):  # noqa: ARG001
    u_budget = cfg.num_features

    def f(q2, k2, v2, subkey):
        n = q2.shape[0]
        m = k2.shape[0]
        u = min(u_budget, n)
        su = min(u_budget, m)
        idx = jax.random.choice(subkey, m, shape=(su,), replace=False)
        sample = q2 @ k2[idx].T  # (n, su)
        sparsity = jnp.max(sample, axis=-1) - jnp.mean(sample, axis=-1)
        # argsort instead of lax.top_k: the old HLO text parser in
        # xla_extension 0.5.1 rejects the `topk(...)` instruction
        # stop_gradient: selection indices are non-differentiable, and the
        # vmapped argsort JVP trips a batched-gather bug in this toolchain
        top = jnp.argsort(jax.lax.stop_gradient(-sparsity))[:u]
        # gather/scatter via one-hot matmuls: vmapped `.at[top].set` lowers
        # to a batched scatter (operand_batching_dims) that the old
        # xla_client converter in this toolchain rejects
        sel = jax.nn.one_hot(top, n, dtype=q2.dtype)  # (u, n)
        qt = sel @ q2  # (u, p)
        attn = common.row_softmax(qt @ k2.T) @ v2  # (u, d_v)
        base = jnp.broadcast_to(jnp.mean(v2, axis=0), (n, v2.shape[1]))
        covered = sel.sum(axis=0)[:, None]  # (n, 1) in {0,1}
        return base * (1.0 - covered) + sel.T @ attn

    return common.map_heads(f, q, k, v, key)
