"""Linformer baseline (Wang et al. 2020).

Johnson–Lindenstrauss compression of keys and values: learned projections
E, F in R^{r x n} give ``softmax(Q (E K)^T / sqrt(p)) (F V)`` — linear in n.
The only baseline here with learnable approximation parameters.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common


def init(key, cfg, seq_len):
    r = cfg.num_features
    ke, kf = jax.random.split(key)
    scale = 1.0 / jnp.sqrt(seq_len)
    return {
        "proj_e": jax.random.normal(ke, (r, seq_len), jnp.float32) * scale,
        "proj_k": jax.random.normal(kf, (r, seq_len), jnp.float32) * scale,
    }


def apply(extra, q, k, v, key, cfg):  # noqa: ARG001
    e, f = extra["proj_e"], extra["proj_k"]

    def g(q2, k2, v2, _key):
        n = k2.shape[0]
        ke = e[:, :n] @ k2  # (r, p)
        vf = f[:, :n] @ v2  # (r, d_v)
        return common.row_softmax(q2 @ ke.T) @ vf

    return common.map_heads(g, q, k, v, key)
