"""Nyströmformer baseline (Xiong et al. 2021).

Applies the Nyström method *directly to the softmax attention matrix* — the
non-PSD usage the Skyformer paper critiques (§2, §4.2 Remark):

    S_hat = softmax(Q L_k^T) pinv(softmax(L_q L_k^T)) softmax(L_q K^T) V

with landmarks L_q, L_k the segment means of Q and K (their released
design), and pinv the same Razavi iteration *without* the Lemma-3
preconditioner (their matrix is not PSD, so the preconditioner's guarantee
does not apply — exactly the paper's point).
"""

from __future__ import annotations

import jax.numpy as jnp

from ..kernels import ref
from . import common


def init(key, cfg, seq_len):  # noqa: ARG001
    return {}


def _segment_means(x: jnp.ndarray, num: int) -> jnp.ndarray:
    """num segment-mean landmarks of the (n, d) matrix x (n padded to num)."""
    n, d = x.shape
    num = min(num, n)
    pad = (-n) % num
    if pad:
        # pad by repeating the mean so padded rows do not bias segments
        x = jnp.concatenate([x, jnp.broadcast_to(x.mean(0), (pad, d))], axis=0)
    return x.reshape(num, -1, d).mean(axis=1)


def apply(extra, q, k, v, key, cfg):  # noqa: ARG001
    num = cfg.num_features

    def f(q2, k2, v2, _key):
        lq = _segment_means(q2, num)
        lk = _segment_means(k2, num)
        f1 = common.row_softmax(q2 @ lk.T)  # (n, d)
        a = common.row_softmax(lq @ lk.T)  # (d, d), non-PSD in general
        f3 = common.row_softmax(lq @ k2.T)  # (d, n)
        z = ref.ns_iterations(a, cfg.ns_iters)
        return f1 @ (z @ (f3 @ v2))

    return common.map_heads(f, q, k, v, key)
