"""Performer baseline (Choromanski et al. 2020), FAVOR+ positive features.

Unbiased softmax-kernel estimator from the Gaussian-integral identity
``exp(x.y) = E_w[exp(w.x - |x|^2/2) exp(w.y - |y|^2/2)]``, w ~ N(0, I):

    phi(x) = exp(W x - |x|^2/2) / sqrt(m),   W: (m, p) orthogonal blocks

    out = phi(Q) (phi(K)^T V) / (phi(Q) (phi(K)^T 1))

Orthogonal random features (QR of Gaussian blocks, row norms resampled from
the chi distribution) for the variance reduction the paper uses.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common


def init(key, cfg, seq_len):  # noqa: ARG001
    return {}


def _gram_schmidt(g: jax.Array) -> jax.Array:
    """Row-orthonormalise a (p, p) Gaussian block.

    Pure jnp (fori_loop of projections) instead of ``jnp.linalg.qr``: QR
    lowers to a TYPED_FFI LAPACK custom-call that xla_extension 0.5.1
    (the rust runtime) cannot execute — see DESIGN.md §6.
    """
    p = g.shape[0]

    def body(i, q):
        v = g[i]
        # subtract projections onto the already-orthonormalised rows (< i)
        mask = (jnp.arange(p) < i).astype(g.dtype)[:, None]
        proj = (q * mask) @ v  # (p,) coefficients; rows >= i are zero
        v = v - (q * mask).T @ proj
        v = v / jnp.maximum(jnp.linalg.norm(v), 1e-6)
        return q.at[i].set(v)

    q0 = jnp.zeros_like(g)
    return jax.lax.fori_loop(0, p, body, q0)


def _orthogonal_features(key: jax.Array, m: int, p: int) -> jax.Array:
    """(m, p) random features with orthogonal p-blocks and chi row norms."""
    blocks = []
    n_blocks = -(-m // p)
    keys = jax.random.split(key, n_blocks + 1)
    for i in range(n_blocks):
        g = jax.random.normal(keys[i], (p, p), jnp.float32)
        blocks.append(_gram_schmidt(g))
    w = jnp.concatenate(blocks, axis=0)[:m]
    # chi(p) row norms = ||N(0, I_p)|| (avoids jax.random.chisquare's
    # gamma-sampling while_loop — heavy in old-XLA text form)
    norms = jnp.linalg.norm(
        jax.random.normal(keys[-1], (m, p), jnp.float32), axis=-1
    )
    return w * norms[:, None]


def apply(extra, q, k, v, key, cfg):  # noqa: ARG001
    m = cfg.num_features

    def f(q2, k2, v2, subkey):
        p = q2.shape[1]
        w = _orthogonal_features(subkey, m, p)

        def phi(x):
            # stabiliser: subtract the max exponent (cancels in the ratio)
            proj = x @ w.T
            sq = 0.5 * jnp.sum(x * x, axis=-1, keepdims=True)
            z = proj - sq
            z = z - jnp.max(z)
            return jnp.exp(z) / jnp.sqrt(m)

        pq, pk = phi(q2), phi(k2)
        num = pq @ (pk.T @ v2)
        den = pq @ jnp.sum(pk, axis=0)[:, None]
        return num / jnp.maximum(den, 1e-6)

    return common.map_heads(f, q, k, v, key)
