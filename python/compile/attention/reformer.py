"""Reformer-style LSH attention baseline (Kitaev et al. 2020), simplified.

Single-round LSH: random-rotation hashing (argmax over [xR, -xR]) buckets
tokens; positions are sorted by (bucket, position); queries attend within
their sorted chunk plus the previous chunk, then results are unsorted.

Simplifications vs. the released Reformer (documented in DESIGN.md):
one hash round, no exact bucket masking inside chunks, and hashing on
(q + k) rather than a tied-QK projection — the chunk budget is
``2 * chunk_size = cfg.num_features`` keys per query, matching the paper's
"128 visited elements per row" control.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common


def init(key, cfg, seq_len):  # noqa: ARG001
    return {}


def apply(extra, q, k, v, key, cfg):  # noqa: ARG001
    chunk = max(8, cfg.num_features // 2)

    def f(q2, k2, v2, subkey):
        n, p = q2.shape
        c = min(chunk, n)
        pad = (-n) % c
        if pad:
            q2 = jnp.pad(q2, ((0, pad), (0, 0)))
            k2 = jnp.pad(k2, ((0, pad), (0, 0)))
            v2 = jnp.pad(v2, ((0, pad), (0, 0)))
        np_ = q2.shape[0]
        nc = np_ // c
        n_buckets = max(2, nc)
        r = jax.random.normal(subkey, (p, n_buckets), jnp.float32)
        logits = (q2 + k2) @ r
        buckets = jnp.argmax(jnp.concatenate([logits, -logits], axis=-1), axis=-1)
        # stable sort by bucket: key = bucket * np_ + position
        order = jnp.argsort(buckets * np_ + jnp.arange(np_))
        inv = jnp.argsort(order)
        qs, ks, vs = q2[order], k2[order], v2[order]
        qc = qs.reshape(nc, c, p)
        kc = ks.reshape(nc, c, p)
        vc = vs.reshape(nc, c, -1)
        # each chunk sees itself + previous chunk (wrap-around)
        kcat = jnp.concatenate([jnp.roll(kc, 1, axis=0), kc], axis=1)
        vcat = jnp.concatenate([jnp.roll(vc, 1, axis=0), vc], axis=1)
        s = jnp.einsum("ncp,nmp->ncm", qc, kcat)
        # mask padded positions (they carry bucket of zero-vectors)
        if pad:
            pos = jnp.concatenate(
                [jnp.roll(order.reshape(nc, c), 1, axis=0), order.reshape(nc, c)],
                axis=1,
            )
            s = jnp.where(pos[:, None, :] < n, s, -1e30)
        w = common.row_softmax(s)
        o = jnp.einsum("ncm,nmd->ncd", w, vcat).reshape(np_, -1)
        return o[inv][:n]

    return common.map_heads(f, q, k, v, key)
