"""Transformer building blocks for the LRA classifier (Layer 2).

Pre-LN blocks (stability — the phenomenon the paper studies is the
*attention* conditioning, not the residual-path variant; DESIGN.md §6), mean
pooling, learned positional embeddings — the 2-layer / 64-dim / 128-ffn /
2-head configuration of the paper's §5.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attention_registry
from .configs import ModelConfig


def dense_init(key: jax.Array, d_in: int, d_out: int) -> dict:
    """Glorot-uniform dense layer parameters."""
    lim = jnp.sqrt(6.0 / (d_in + d_out))
    w = jax.random.uniform(key, (d_in, d_out), jnp.float32, -lim, lim)
    return {"w": w, "b": jnp.zeros((d_out,), jnp.float32)}


def dense(p: dict, x: jax.Array) -> jax.Array:
    return x @ p["w"] + p["b"]


def layer_norm_init(dim: int) -> dict:
    return {"scale": jnp.ones((dim,), jnp.float32), "bias": jnp.zeros((dim,), jnp.float32)}


def layer_norm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]


def block_init(key: jax.Array, cfg: ModelConfig, seq_len: int) -> dict:
    kq, kk, kv, ko, k1, k2, ka = jax.random.split(key, 7)
    e = cfg.emb_dim
    attn_mod = attention_registry.get(cfg.attention)
    return {
        "ln1": layer_norm_init(e),
        "wq": dense_init(kq, e, e),
        "wk": dense_init(kk, e, e),
        "wv": dense_init(kv, e, e),
        "wo": dense_init(ko, e, e),
        "attn": attn_mod.init(ka, cfg, seq_len),
        "ln2": layer_norm_init(e),
        "ff1": dense_init(k1, e, cfg.ffn_dim),
        "ff2": dense_init(k2, cfg.ffn_dim, e),
    }


def _split_heads(x: jax.Array, num_heads: int) -> jax.Array:
    b, n, e = x.shape
    return x.reshape(b, n, num_heads, e // num_heads).transpose(0, 2, 1, 3)


def _merge_heads(x: jax.Array) -> jax.Array:
    b, h, n, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, n, h * d)


def block_apply(p: dict, x: jax.Array, key: jax.Array, cfg: ModelConfig) -> jax.Array:
    """One pre-LN transformer block with the configured attention."""
    attn_mod = attention_registry.get(cfg.attention)
    h = layer_norm(p["ln1"], x)
    q = _split_heads(dense(p["wq"], h), cfg.num_heads)
    k = _split_heads(dense(p["wk"], h), cfg.num_heads)
    v = _split_heads(dense(p["wv"], h), cfg.num_heads)
    # pre-scale q and k by p^-1/4: q.k^T == QK^T/sqrt(p), Gaussian bandwidth p^1/4
    scale = float(cfg.head_dim) ** -0.25
    out = attn_mod.apply(p["attn"], q * scale, k * scale, v, key, cfg)
    x = x + dense(p["wo"], _merge_heads(out))
    h = layer_norm(p["ln2"], x)
    h = jax.nn.gelu(dense(p["ff1"], h))
    return x + dense(p["ff2"], h)
