"""Pallas kernel for the vanilla softmax-attention baseline.

Online-softmax (flash-attention style) schedule: grid over query tiles, each
program streams K/V tiles carrying ``(running_max, running_denominator,
accumulator)`` so no (n, m) matrix is ever materialised.  This is the TPU
remapping of the paper's baseline — the shared-memory row-max of a CUDA
flash kernel becomes a VMEM/register carry in the K-tile loop.

Numerics match ``ref.softmax_attention`` to f32 roundoff; pytest enforces it
over hypothesis-generated shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .gaussian import _pad_rows

_NEG_INF = -1e30


def _sm_program(q_ref, k_ref, v_ref, o_ref, *, block_k: int, m_actual: int):
    q = q_ref[...].astype(jnp.float32)  # (block_q, p)
    bq = q.shape[0]
    d_v = v_ref.shape[1]
    m_padded = k_ref.shape[0]
    steps = m_padded // block_k

    def body(j, carry):
        m_i, l_i, acc = carry
        k = pl.load(k_ref, (pl.dslice(j * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(j * block_k, block_k), slice(None)))
        s = jnp.dot(q, k.T.astype(jnp.float32), preferred_element_type=jnp.float32)
        idx = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        s = jnp.where(idx < m_actual, s, _NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1, keepdims=True))
        scale = jnp.exp(m_i - m_new)
        p_ij = jnp.exp(s - m_new)
        l_new = l_i * scale + jnp.sum(p_ij, axis=-1, keepdims=True)
        acc = acc * scale + jnp.dot(
            p_ij, v.astype(jnp.float32), preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc

    init = (
        jnp.full((bq, 1), _NEG_INF, jnp.float32),
        jnp.zeros((bq, 1), jnp.float32),
        jnp.zeros((bq, d_v), jnp.float32),
    )
    _, l_i, acc = jax.lax.fori_loop(0, steps, body, init)
    o_ref[...] = acc / jnp.maximum(l_i, 1e-30)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def softmax_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """``softmax(q k^T) v`` on pre-scaled q/k (scale 1/sqrt(p) folded in)."""
    n, _ = q.shape
    m, _ = k.shape
    block_q = min(block_q, max(8, n))
    block_k = min(block_k, max(8, m))
    qp = _pad_rows(q, block_q)
    kp = _pad_rows(k, block_k)
    vp = _pad_rows(v, block_k)
    n_pad, p = qp.shape
    m_pad = kp.shape[0]
    d_v = vp.shape[1]

    out = pl.pallas_call(
        functools.partial(_sm_program, block_k=block_k, m_actual=m),
        grid=(n_pad // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, p), lambda i: (i, 0)),
            pl.BlockSpec((m_pad, p), lambda i: (0, 0)),
            pl.BlockSpec((m_pad, d_v), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, d_v), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, d_v), jnp.float32),
        interpret=True,
    )(qp, kp, vp)
    return out[:n]
