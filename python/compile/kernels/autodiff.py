"""Differentiable wrappers around the Pallas kernels.

``pallas_call`` has no transpose rule (in interpret mode or otherwise), so
the training graphs cannot backprop through the raw kernels.  These wrappers
pair the Pallas **forward** with the VJP of the mathematically identical
pure-jnp reference (kernels.ref) as the **backward** — the standard
fwd-kernel/bwd-kernel pairing, with the bwd half currently the XLA-fused
reference.  pytest asserts both halves agree with finite differences.

Dedicated Pallas backward kernels (flash-style recomputation) are the
natural extension; the paper's contribution is the forward approximation,
so the fused backward preserves every claim under test.
"""

from __future__ import annotations

import jax

from . import gaussian as _gaussian
from . import nystrom as _nystrom
from . import ref as _ref
from . import softmax as _softmax


@jax.custom_vjp
def kernelized_attention(q, k, v):
    """Pallas kernelized attention with a differentiable (ref-VJP) backward."""
    return _gaussian.kernelized_attention(q, k, v)


def _ka_fwd(q, k, v):
    return _gaussian.kernelized_attention(q, k, v), (q, k, v)


def _ka_bwd(res, g):
    q, k, v = res
    return jax.vjp(_ref.kernelized_attention, q, k, v)[1](g)


kernelized_attention.defvjp(_ka_fwd, _ka_bwd)


@jax.custom_vjp
def softmax_attention(q, k, v):
    """Pallas online-softmax attention with a differentiable backward."""
    return _softmax.softmax_attention(q, k, v)


def _sm_fwd(q, k, v):
    return _softmax.softmax_attention(q, k, v), (q, k, v)


def _sm_bwd(res, g):
    q, k, v = res
    return jax.vjp(_ref.softmax_attention, q, k, v)[1](g)


softmax_attention.defvjp(_sm_fwd, _sm_bwd)


import functools

import numpy as np


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def skyformer_attention(q, k, v, landmarks, gamma: float = 1e-3, iters: int = 6):
    """Pallas Skyformer with a differentiable backward.

    ``landmarks`` is an integer primal (sampled fresh per step); its
    cotangent is the float0 zero JAX requires for integer inputs.  Gradients
    w.r.t. q and k include the landmark-gather path (landmark rows *are*
    rows of [Q; K]), exactly as in the reference.
    """
    return _nystrom.skyformer_attention(q, k, v, landmarks, gamma=gamma, iters=iters)


def _sky_fwd(q, k, v, landmarks, gamma, iters):
    out = _nystrom.skyformer_attention(q, k, v, landmarks, gamma=gamma, iters=iters)
    return out, (q, k, v, landmarks)


def _sky_bwd(gamma, iters, res, g):
    q, k, v, landmarks = res

    def ref_fn(q, k, v):
        return _ref.skyformer_attention(q, k, v, landmarks, gamma=gamma, iters=iters)

    dq, dk, dv = jax.vjp(ref_fn, q, k, v)[1](g)
    d_lmk = np.zeros(landmarks.shape, dtype=jax.dtypes.float0)
    return dq, dk, dv, d_lmk


skyformer_attention.defvjp(_sky_fwd, _sky_bwd)
