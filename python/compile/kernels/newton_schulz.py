"""Pallas kernel for the preconditioned Newton–Schulz pseudo-inverse (§4.4).

The paper's workaround for slow/unstable on-accelerator ``inv``: a
matrix-product-only iteration (Razavi et al.) applied to the Lemma-3
preconditioned matrix ``D_M^{-1/2}(M + gamma I) D_M^{-1/2}`` whose singular
values provably lie in (0, 1).

The whole (d, d) landmark Gram matrix fits in VMEM for every d the paper
uses (d <= 256 → 256 KiB f32), so this is a single-program kernel: the grid
is trivial and the iteration is a ``fori_loop`` of MXU-shaped matmuls —
exactly the "no division, only GEMMs" property the paper wants on GPU, which
holds even more strongly on the MXU (no native inverse at all).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ns_program(m_ref, o_ref, *, gamma: float, iters: int):
    m = m_ref[...].astype(jnp.float32)
    d = m.shape[0]
    eye = jnp.eye(d, dtype=jnp.float32)
    mg = m + gamma * eye

    # Lemma-3 preconditioner: D = diag(mg @ 1).
    row = jnp.sum(mg, axis=1)
    d_inv_sqrt = jax.lax.rsqrt(jnp.maximum(row, 1e-30))
    a = d_inv_sqrt[:, None] * mg * d_inv_sqrt[None, :]

    # Z0 = A^T / (||A||_1 ||A||_inf): convergent for any matrix.
    n1 = jnp.max(jnp.sum(jnp.abs(a), axis=0))
    ninf = jnp.max(jnp.sum(jnp.abs(a), axis=1))
    z = a.T / jnp.maximum(n1 * ninf, 1e-30)

    def body(_, z):
        az = jnp.dot(a, z, preferred_element_type=jnp.float32)
        t1 = 7.0 * eye - az
        t2 = 15.0 * eye - jnp.dot(az, t1, preferred_element_type=jnp.float32)
        t3 = 13.0 * eye - jnp.dot(az, t2, preferred_element_type=jnp.float32)
        return 0.25 * jnp.dot(z, t3, preferred_element_type=jnp.float32)

    z = jax.lax.fori_loop(0, iters, body, z)
    # Undo the preconditioning: (M+gI)^{-1} = D^{-1/2} A^{-1} D^{-1/2}.
    o_ref[...] = d_inv_sqrt[:, None] * z * d_inv_sqrt[None, :]


@functools.partial(jax.jit, static_argnames=("gamma", "iters"))
def ns_inverse(m: jax.Array, *, gamma: float = 1e-3, iters: int = 6) -> jax.Array:
    """Approximate ``(M + gamma I)^{-1}`` of a PSD (d, d) ``m``."""
    d = m.shape[0]
    return pl.pallas_call(
        functools.partial(_ns_program, gamma=gamma, iters=iters),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        interpret=True,
    )(m)
