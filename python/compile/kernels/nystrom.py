"""Pallas kernels for the modified Nyström method (Skyformer, §4.2).

The Skyformer product

    out = kappa(Q, L) · (kappa(L, L) + gamma I)^{-1} · kappa(L, K) · V

(L = landmark rows of the lifted design matrix [Q; K]) decomposes into four
stages, each with its own HBM↔VMEM schedule:

  1. ``kv = kappa(L, K) @ V`` — the streaming kernelized-attention kernel
     with the d landmark rows as queries (gaussian.kernelized_attention):
     K/V are visited once, nothing (n, ·) is materialised.
  2. ``M = kappa(L, L)`` — (d, d), single tile (gaussian.gaussian_scores).
  3. ``inv ≈ (M + gamma I)^{-1}`` — Newton–Schulz kernel (newton_schulz).
  4. ``out = kappa(Q, L) @ (inv @ kv)`` — the combine kernel below: grid
     over query tiles; each program computes its Gaussian block against the
     (small, VMEM-resident) landmarks and immediately contracts with the
     precomputed (d, d_v) weight, so the (n, d) score block never leaves
     VMEM.

Total complexity O(n·d·p + d^3) versus O(n^2·p) for the exact kernel —
the paper's headline efficiency claim, structurally enforced: no
intermediate of size (n, n) or even (n, d) hits HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .gaussian import _pad_rows, gaussian_scores, kernelized_attention
from .newton_schulz import ns_inverse


def _combine_program(q_ref, lm_ref, w_ref, o_ref):
    """o = kappa(q_tile, L) @ w, fused so the score block stays in VMEM."""
    q = q_ref[...].astype(jnp.float32)  # (block_q, p)
    lm = lm_ref[...].astype(jnp.float32)  # (d, p)
    w = w_ref[...].astype(jnp.float32)  # (d, d_v)
    qn = 0.5 * jnp.sum(q * q, axis=-1, keepdims=True)
    ln = 0.5 * jnp.sum(lm * lm, axis=-1)
    s = jnp.exp(jnp.dot(q, lm.T, preferred_element_type=jnp.float32) - qn - ln[None, :])
    o_ref[...] = jnp.dot(s, w, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_q",))
def _combine(q: jax.Array, lm: jax.Array, w: jax.Array, *, block_q: int = 128) -> jax.Array:
    n = q.shape[0]
    block_q = min(block_q, max(8, n))
    qp = _pad_rows(q, block_q)
    n_pad, p = qp.shape
    d, d_v = w.shape
    out = pl.pallas_call(
        _combine_program,
        grid=(n_pad // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, p), lambda i: (i, 0)),
            pl.BlockSpec((d, p), lambda i: (0, 0)),
            pl.BlockSpec((d, d_v), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, d_v), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, d_v), jnp.float32),
        interpret=True,
    )(qp, lm, w)
    return out[:n]


def skyformer_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    landmarks: jax.Array,
    *,
    gamma: float = 1e-3,
    iters: int = 6,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """Skyformer attention on pre-scaled (n,p) q, (m,p) k, (m,d_v) v.

    ``landmarks``: (d,) int indices into the 2n rows of ``[Q; K]``
    (the uniform sub-sampling matrix S of Definition 1; its 1/sqrt(d)
    scaling cancels in B S (S^T B S)^+ S^T B).
    """
    x = jnp.concatenate([q, k], axis=0)
    lm = x[landmarks].astype(jnp.float32)  # (d, p)
    kv = kernelized_attention(lm, k, v, block_q=block_q, block_k=block_k)  # (d, d_v)
    m = gaussian_scores(lm, lm)  # (d, d)
    inv = ns_inverse(m, gamma=gamma, iters=iters)  # (d, d)
    w = inv @ kv  # (d, d_v): tiny, fused by XLA
    return _combine(q, lm, w, block_q=block_q)


def landmark_gram(q: jax.Array, k: jax.Array, landmarks: jax.Array) -> jax.Array:
    """``S^T C_bar S = kappa(L, L)`` — exposed for tests of Lemma 3."""
    x = jnp.concatenate([q, k], axis=0)
    lm = x[landmarks]
    return gaussian_scores(lm, lm)
