"""Layer-1 Pallas kernels (interpret=True) + pure-jnp reference oracles.

Public surface:

* ``gaussian.kernelized_attention`` / ``gaussian.gaussian_scores``
* ``softmax.softmax_attention``
* ``newton_schulz.ns_inverse``
* ``nystrom.skyformer_attention`` / ``nystrom.landmark_gram``
* ``ref.*`` — the oracles every kernel is tested against
"""

from . import gaussian, newton_schulz, nystrom, ref, softmax  # noqa: F401
