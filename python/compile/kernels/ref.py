"""Pure-jnp reference oracles for every Layer-1 Pallas kernel.

These are the correctness ground truth: pytest (including hypothesis shape
sweeps) asserts each Pallas kernel in this package is allclose to the
corresponding function here.  They are also the "fused" lowering path used
inside the long-running training artifacts (see compile/attention/*), so the
training graphs and the Pallas kernels are pinned to the same math.

Conventions
-----------
* All attention-style functions take *pre-scaled* queries/keys: callers
  multiply both ``q`` and ``k`` by ``p**-0.25`` so that ``q @ k.T`` equals
  ``QK^T / sqrt(p)`` and the Gaussian kernel has the paper's bandwidth
  ``p**(1/4)`` (Skyformer Eq. (1)/(3)).
* Everything is f32-accumulated; inputs may be f32 or bf16.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sq_half_norms(x: jax.Array) -> jax.Array:
    """Row-wise ``||x_i||^2 / 2`` as an (n,) f32 vector."""
    x = x.astype(jnp.float32)
    return 0.5 * jnp.sum(x * x, axis=-1)


def gaussian_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """Empirical Gaussian kernel matrix ``kappa(q_i, k_j) = exp(-||q_i-k_j||^2/2)``.

    Expanded as ``exp(q.k - ||q||^2/2 - ||k||^2/2)`` so the hot op is a single
    matmul (the form the Pallas kernel tiles).
    """
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    return jnp.exp(q @ k.T - sq_half_norms(q)[:, None] - sq_half_norms(k)[None, :])


def kernelized_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Kernelized Attention (paper Eq. (3)): ``C @ V`` with C = gaussian_scores.

    No softmax normalisation: the Gaussian kernel's ``exp(-d^2/2)`` form *is*
    the normalisation (C = D_Q^{-1/2} A D_K^{-1/2}, paper §4.1).
    """
    return gaussian_scores(q, k) @ v.astype(jnp.float32)


def softmax_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Vanilla attention ``softmax(q k^T) v`` on pre-scaled q/k."""
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    s = q @ k.T
    s = s - jnp.max(s, axis=-1, keepdims=True)
    w = jnp.exp(s)
    return (w / jnp.sum(w, axis=-1, keepdims=True)) @ v.astype(jnp.float32)


def lifted_gaussian(q: jax.Array, k: jax.Array) -> jax.Array:
    """PSD completion ``C_bar = kappa([Q;K], [Q;K])`` (paper Eq. (4))."""
    x = jnp.concatenate([q, k], axis=0)
    return gaussian_scores(x, x)


def ns_preconditioner(m: jax.Array, gamma: float) -> tuple[jax.Array, jax.Array]:
    """Lemma-3 preconditioning of a PSD ``m``.

    Returns ``(m_hat, d_inv_sqrt)`` with
    ``m_hat = D^{-1/2} (M + gamma I) D^{-1/2}``, ``D = diag((M + gamma I) 1)``.
    Lemma 3 guarantees all singular values of ``m_hat`` lie in (0, 1), hence
    ``||I - m_hat|| < 1`` and the Newton–Schulz iteration below converges.
    """
    m = m.astype(jnp.float32)
    d = m.shape[0]
    mg = m + gamma * jnp.eye(d, dtype=jnp.float32)
    row = jnp.sum(mg, axis=1)
    d_inv_sqrt = jax.lax.rsqrt(jnp.maximum(row, 1e-30))
    m_hat = d_inv_sqrt[:, None] * mg * d_inv_sqrt[None, :]
    return m_hat, d_inv_sqrt


def ns_iterations(m_hat: jax.Array, iters: int) -> jax.Array:
    """Razavi-type (order-3 hyperpower) iteration for ``m_hat^{-1}``.

    ``Z_{t+1} = 1/4 Z_t (13 I - A Z_t (15 I - A Z_t (7 I - A Z_t)))`` — the
    division-free scheme the paper adapts from Nyströmformer (§4.4), seeded
    with ``Z_0 = A^T / (||A||_1 ||A||_inf)`` which converges for any A.
    """
    a = m_hat.astype(jnp.float32)
    d = a.shape[0]
    eye = jnp.eye(d, dtype=jnp.float32)
    n1 = jnp.max(jnp.sum(jnp.abs(a), axis=0))
    ninf = jnp.max(jnp.sum(jnp.abs(a), axis=1))
    z = a.T / jnp.maximum(n1 * ninf, 1e-30)

    def body(_, z):
        az = a @ z
        return 0.25 * z @ (13.0 * eye - az @ (15.0 * eye - az @ (7.0 * eye - az)))

    return jax.lax.fori_loop(0, iters, body, z)


def ns_inverse(m: jax.Array, gamma: float = 1e-3, iters: int = 6) -> jax.Array:
    """Approximate ``(M + gamma I)^{-1}`` of a PSD M via preconditioned NS.

    ``(M+gI)^{-1} = D^{-1/2} m_hat^{-1} D^{-1/2}`` — the workaround of §4.4.
    """
    m_hat, d_inv_sqrt = ns_preconditioner(m, gamma)
    z = ns_iterations(m_hat, iters)
    return d_inv_sqrt[:, None] * z * d_inv_sqrt[None, :]


def skyformer_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    landmarks: jax.Array,
    gamma: float = 1e-3,
    iters: int = 6,
    exact_pinv: bool = False,
) -> jax.Array:
    """Skyformer (paper Eq. (4)-(6)) on pre-scaled q/k.

    ``landmarks`` is an (d,) int array of row indices into ``[Q; K]``
    (the uniform sub-sampling S; the 1/sqrt(d) column scaling of
    Definition 1 cancels algebraically in B S (S^T B S)^+ S^T B).

    Output: ``kappa(Q, L) (kappa(L, L) + gamma I)^{-1} kappa(L, K) V`` — the
    top-right n-by-n block of the Nyström approximation of the lifted PSD
    matrix C_bar, applied to V without materialising any n-by-n matrix.
    """
    x = jnp.concatenate([q, k], axis=0).astype(jnp.float32)
    lm = x[landmarks]  # (d, p)
    c_ql = gaussian_scores(q, lm)  # (n, d)
    c_lk = gaussian_scores(lm, k)  # (d, n)
    m = gaussian_scores(lm, lm)  # (d, d) PSD
    if exact_pinv:
        d = m.shape[0]
        inv = jnp.linalg.pinv(m + gamma * jnp.eye(d, dtype=jnp.float32))
    else:
        inv = ns_inverse(m, gamma=gamma, iters=iters)
    return c_ql @ (inv @ (c_lk @ v.astype(jnp.float32)))


def skyformer_scores(
    q: jax.Array,
    k: jax.Array,
    landmarks: jax.Array,
    gamma: float = 1e-3,
    iters: int = 6,
) -> jax.Array:
    """Materialised n-by-n Skyformer score matrix (tests / approx study only)."""
    n = q.shape[0]
    eye = jnp.eye(n, dtype=jnp.float32)
    return skyformer_attention(q, k, eye, landmarks, gamma=gamma, iters=iters)


def uniform_landmarks(key: jax.Array, two_n: int, d: int) -> jax.Array:
    """Sample d landmark indices from [0, 2n) without replacement.

    Definition 1 samples with replacement; without-replacement is the
    strictly-lower-variance practical variant (DESIGN.md §6).
    """
    return jax.random.choice(key, two_n, shape=(d,), replace=False)
