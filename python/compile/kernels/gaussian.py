"""Pallas kernel for Kernelized Attention (paper Eq. (3)).

Schedule (the TPU remapping of the paper's V100 threadblock tiling, see
DESIGN.md §Hardware-Adaptation):

* grid over query-row tiles (``block_q`` rows each) — one program per tile;
* each program streams K/V in ``block_k``-row tiles with a ``fori_loop``,
  holding a ``(block_q, d_v)`` f32 accumulator in VMEM/registers;
* the Gaussian kernel is computed in its matmul form
  ``exp(q.k - ||q||^2/2 - ||k||^2/2)`` so the inner op is an MXU-shaped dot.

VMEM footprint per program ≈ ``block_q*p + block_k*(p + d_v) + block_q*d_v``
f32 words — with the default blocks (128, 128) and p = d_v = 64 that is
~0.26 MiB, far under a TensorCore's 16 MiB VMEM, leaving room for
double-buffered K/V streaming on real hardware.

``interpret=True`` always: real-TPU lowering emits a Mosaic custom-call the
CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ka_program(q_ref, k_ref, v_ref, o_ref, *, block_k: int, m_actual: int):
    """One query tile of kernelized attention: ``o = kappa(q, K) @ V``."""
    q = q_ref[...].astype(jnp.float32)  # (block_q, p)
    qn = 0.5 * jnp.sum(q * q, axis=-1, keepdims=True)  # (block_q, 1)
    m_padded = k_ref.shape[0]
    d_v = v_ref.shape[1]
    steps = m_padded // block_k

    def body(j, acc):
        k = pl.load(k_ref, (pl.dslice(j * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(j * block_k, block_k), slice(None)))
        k = k.astype(jnp.float32)
        kn = 0.5 * jnp.sum(k * k, axis=-1)  # (block_k,)
        s = jnp.exp(jnp.dot(q, k.T, preferred_element_type=jnp.float32) - qn - kn[None, :])
        # Zero the contribution of padded key rows (kappa(q, 0) != 0).
        idx = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        s = jnp.where(idx < m_actual, s, 0.0)
        return acc + jnp.dot(s, v.astype(jnp.float32), preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(
        0, steps, body, jnp.zeros((q.shape[0], d_v), jnp.float32)
    )
    o_ref[...] = acc


def _pad_rows(x: jax.Array, multiple: int) -> jax.Array:
    n = x.shape[0]
    pad = (-n) % multiple
    if pad == 0:
        return x
    return jnp.pad(x, ((0, pad), (0, 0)))


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def kernelized_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """``kappa(q, k) @ v`` for pre-scaled (n,p) q, (m,p) k, (m,d_v) v.

    Arbitrary n/m are handled by zero-padding to block multiples; padded key
    rows are masked inside the kernel, padded query rows are sliced off here.
    """
    n, _ = q.shape
    m, _ = k.shape
    block_q = min(block_q, max(8, n))
    block_k = min(block_k, max(8, m))
    qp = _pad_rows(q, block_q)
    kp = _pad_rows(k, block_k)
    vp = _pad_rows(v, block_k)
    n_pad, p = qp.shape
    m_pad = kp.shape[0]
    d_v = vp.shape[1]

    out = pl.pallas_call(
        functools.partial(_ka_program, block_k=block_k, m_actual=m),
        grid=(n_pad // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, p), lambda i: (i, 0)),
            pl.BlockSpec((m_pad, p), lambda i: (0, 0)),
            pl.BlockSpec((m_pad, d_v), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, d_v), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, d_v), jnp.float32),
        interpret=True,
    )(qp, kp, vp)
    return out[:n]


def _scores_program(q_ref, k_ref, o_ref, *, m_actual: int):
    """Materialised Gaussian score tile ``kappa(q_tile, K)`` (study/tests)."""
    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    qn = 0.5 * jnp.sum(q * q, axis=-1, keepdims=True)
    kn = 0.5 * jnp.sum(k * k, axis=-1)
    s = jnp.exp(jnp.dot(q, k.T, preferred_element_type=jnp.float32) - qn - kn[None, :])
    idx = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    o_ref[...] = jnp.where(idx < m_actual, s, 0.0)


@functools.partial(jax.jit, static_argnames=("block_q",))
def gaussian_scores(q: jax.Array, k: jax.Array, *, block_q: int = 128) -> jax.Array:
    """Full (n, m) Gaussian kernel matrix via the tiled Pallas program."""
    n = q.shape[0]
    m = k.shape[0]
    block_q = min(block_q, max(8, n))
    qp = _pad_rows(q, block_q)
    n_pad, p = qp.shape

    out = pl.pallas_call(
        functools.partial(_scores_program, m_actual=m),
        grid=(n_pad // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, p), lambda i: (i, 0)),
            pl.BlockSpec((m, p), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, m), jnp.float32),
        interpret=True,
    )(qp, k)
    return out[:n]
