"""The LRA classifier (Layer 2): paper §5's 2-layer transformer.

Single-tower for ListOps / Text / Pathfinder / Image; dual-tower (shared
encoder, feature-interaction head) for Retrieval — the LRA protocol.

All functions are pure: ``params`` is a pytree, randomness enters through an
explicit key (consumed by the stochastic attention approximators).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .configs import ModelConfig, TaskConfig


def init_params(key: jax.Array, task: TaskConfig, cfg: ModelConfig) -> dict:
    ke, kp, kh, *kb = jax.random.split(key, 3 + cfg.num_layers)
    e = cfg.emb_dim
    head_in = 3 * e if task.dual else e
    return {
        "embed": jax.random.normal(ke, (task.vocab_size, e), jnp.float32) * 0.02,
        "pos": jax.random.normal(kp, (task.seq_len, e), jnp.float32) * 0.02,
        "blocks": [layers.block_init(k, cfg, task.seq_len) for k in kb],
        "ln_f": layers.layer_norm_init(e),
        "head": layers.dense_init(kh, head_in, task.num_classes),
    }


def encode(params: dict, tokens: jax.Array, key: jax.Array, cfg: ModelConfig) -> jax.Array:
    """(B, N) int32 tokens -> (B, E) mean-pooled features."""
    x = params["embed"][tokens] + params["pos"][None, : tokens.shape[1]]
    keys = jax.random.split(key, len(params["blocks"]))
    for p_block, k_block in zip(params["blocks"], keys):
        x = layers.block_apply(p_block, x, k_block, cfg)
    x = layers.layer_norm(params["ln_f"], x)
    return jnp.mean(x, axis=1)


def forward(params: dict, tokens: jax.Array, key: jax.Array, task: TaskConfig, cfg: ModelConfig) -> jax.Array:
    """Logits. ``tokens``: (B, N) int32, or (B, 2, N) for dual-tower tasks."""
    if task.dual:
        k1, k2 = jax.random.split(key)
        e1 = encode(params, tokens[:, 0], k1, cfg)
        e2 = encode(params, tokens[:, 1], k2, cfg)
        feats = jnp.concatenate([e1, e2, e1 * e2], axis=-1)
    else:
        feats = encode(params, tokens, key, cfg)
    return layers.dense(params["head"], feats)


def token_shape(task: TaskConfig) -> tuple[int, ...]:
    """Shape of one batch of tokens for this task."""
    if task.dual:
        return (task.batch_size, 2, task.seq_len)
    return (task.batch_size, task.seq_len)
