"""Properties of the modified Nyström method — the paper's §4.2–§4.4 claims.

These are the *mathematical* invariants (Lemma 1, Lemma 3, Theorem 2's
error form, and the §4.5 monotonicity claim), tested numerically.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

SETTINGS = dict(deadline=None, max_examples=10)


def _qk(seed: int, n: int, p: int, scale=0.7):
    key = jax.random.PRNGKey(seed)
    kq, kk = jax.random.split(key)
    q = jax.random.normal(kq, (n, p), jnp.float32) * scale
    k = jax.random.normal(kk, (n, p), jnp.float32) * scale
    return q, k


@given(st.integers(2, 120), st.sampled_from([4, 16, 32]), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_lemma1_lifted_matrix_is_psd(n, p, seed):
    """Lemma 1 / Eq. (4): C_bar = kappa([Q;K],[Q;K]) is PSD."""
    q, k = _qk(seed, n, p)
    cbar = np.asarray(ref.lifted_gaussian(q, k))
    np.testing.assert_allclose(cbar, cbar.T, atol=1e-6)
    w = np.linalg.eigvalsh(cbar)
    assert w.min() > -1e-3 * max(1.0, w.max())


@given(st.integers(2, 80), st.sampled_from([4, 16]), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_lemma3_preconditioned_singular_values_in_unit_interval(n, p, seed):
    """Lemma 3: all singular values of D^{-1/2}(M+gI)D^{-1/2} in (0,1)."""
    q, k = _qk(seed, n, p)
    d = min(32, 2 * n)
    lmk = ref.uniform_landmarks(jax.random.PRNGKey(seed ^ 1), 2 * n, d)
    x = jnp.concatenate([q, k], axis=0)[lmk]
    m = ref.gaussian_scores(x, x)
    m_hat, _ = ref.ns_preconditioner(m, gamma=1e-3)
    # strict in exact arithmetic; f32 rounding can land exactly on 1.0
    sv = np.linalg.svd(np.asarray(m_hat, dtype=np.float64), compute_uv=False)
    assert sv.max() <= 1.0 + 1e-6
    assert sv.min() > 0.0
    # the exact statement: ||I - m_hat|| < 1
    resid = np.linalg.norm(np.eye(m.shape[0]) - np.asarray(m_hat, np.float64), 2)
    assert resid < 1.0 + 1e-6


def test_ns_iteration_converges_to_inverse():
    """NS residual decreases monotonically to ~0 on a preconditioned PSD M."""
    q, k = _qk(42, 64, 16)
    lmk = ref.uniform_landmarks(jax.random.PRNGKey(7), 128, 48)
    x = jnp.concatenate([q, k], axis=0)[lmk]
    m = ref.gaussian_scores(x, x)
    m_hat, _ = ref.ns_preconditioner(m, gamma=1e-3)
    eye = np.eye(48, dtype=np.float32)
    prev = np.inf
    for iters in (1, 3, 6, 10, 16):
        z = np.asarray(ref.ns_iterations(m_hat, iters))
        resid = np.linalg.norm(eye - np.asarray(m_hat) @ z, 2)
        assert resid <= prev + 1e-5, f"residual rose at iters={iters}"
        prev = resid
    assert prev < 1e-4


def test_theorem2_error_form():
    """||C_tilde - C|| <= lambda where C_tilde uses exact pinv and
    lambda is calibrated from the tail eigenvalues of C_bar.

    Theorem 2 is probabilistic in S; here we check the deterministic core:
    the Nyström error of the lifted PSD matrix upper-bounds the off-diagonal
    block error (Eq. after (6)), and grows no faster than the tail mass.
    """
    n, p, d = 96, 16, 64
    q, k = _qk(3, n, p, scale=0.5)
    c = np.asarray(ref.gaussian_scores(q, k))
    cbar = np.asarray(ref.lifted_gaussian(q, k))
    lmk = np.asarray(ref.uniform_landmarks(jax.random.PRNGKey(1), 2 * n, d))
    # full lifted Nystrom: C_bar S (S^T C_bar S)^+ S^T C_bar
    cs = cbar[:, lmk]
    w = np.linalg.pinv(cbar[np.ix_(lmk, lmk)], rcond=1e-10)
    cbar_tilde = cs @ w @ cs.T
    block = cbar_tilde[:n, n:]
    err_block = np.linalg.norm(c - block, 2)
    err_lift = np.linalg.norm(cbar - cbar_tilde, 2)
    # ||C - C_tilde|| = ||(I,0)(Cbar - Cbar_tilde)(0,I)^T|| <= ||Cbar - Cbar_tilde||
    assert err_block <= err_lift + 1e-4
    # Loewner sandwich Theorem 2: 0 <= Cbar - Cbar_tilde (PSD residual)
    resid_eigs = np.linalg.eigvalsh(cbar - cbar_tilde)
    assert resid_eigs.min() > -1e-3 * max(1.0, resid_eigs.max())


def test_nystrom_error_monotone_in_features():
    """§4.5 claim: Skyformer error decreases as the number of features grows."""
    n, p = 128, 16
    q, k = _qk(11, n, p, scale=0.4)
    c = np.asarray(ref.gaussian_scores(q, k))
    errs = []
    for d in (8, 32, 128, 256):
        tries = []
        for s in range(3):
            lmk = ref.uniform_landmarks(jax.random.PRNGKey(100 * d + s), 2 * n, d)
            approx = np.asarray(ref.skyformer_scores(q, k, lmk, iters=12))
            tries.append(np.linalg.norm(c - approx, 2))
        errs.append(np.mean(tries))
    assert errs[-1] < errs[0] * 0.5, f"no decay: {errs}"
    assert all(errs[i + 1] <= errs[i] * 1.25 for i in range(len(errs) - 1)), errs


def test_full_landmarks_recover_exact_matrix():
    """With all 2n rows as landmarks the Nyström approximation is exact."""
    n, p = 40, 8
    q, k = _qk(5, n, p, scale=0.5)
    c = np.asarray(ref.gaussian_scores(q, k))
    lmk = jnp.arange(2 * n)
    approx = np.asarray(ref.skyformer_scores(q, k, lmk, gamma=1e-6, iters=30))
    np.testing.assert_allclose(approx, c, atol=5e-3)


def test_kernelized_attention_equals_normalized_softmax_numerator():
    """§4.1: C = D_Q^{-1/2} A D_K^{-1/2} with A = exp(QK^T/sqrt(p))."""
    n, p = 50, 16
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (n, p)) * 0.5
    k = jax.random.normal(jax.random.split(key)[0], (n, p)) * 0.5
    scale = p**-0.25
    c = np.asarray(ref.gaussian_scores(q * scale, k * scale))
    a = np.exp(np.asarray(q) @ np.asarray(k).T / np.sqrt(p))
    dq = np.exp(np.sum(np.asarray(q) ** 2, -1) / np.sqrt(p))
    dk = np.exp(np.sum(np.asarray(k) ** 2, -1) / np.sqrt(p))
    want = dq[:, None] ** -0.5 * a * dk[None, :] ** -0.5
    np.testing.assert_allclose(c, want, rtol=1e-4)
