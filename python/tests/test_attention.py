"""L2 attention-module contract tests: every registry entry obeys the same
interface, is finite, has the right shape, and the stochastic approximators
actually approximate their targets.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import attention, configs
from compile.kernels import ref

B, H, N, D = 2, 2, 128, 16


def _qkv(seed=0, n=N):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    shape = (B, H, n, D)
    scale = D**-0.25
    q = jax.random.normal(kq, shape) * 0.5 * scale
    k = jax.random.normal(kk, shape) * 0.5 * scale
    v = jax.random.normal(kv, (B, H, n, D))
    return q, k, v


@pytest.mark.parametrize("name", configs.ATTENTION_KINDS)
def test_shape_and_finiteness(name):
    cfg = configs.model_for(name, num_features=32)
    mod = attention.get(name)
    q, k, v = _qkv()
    extra = mod.init(jax.random.PRNGKey(1), cfg, N)
    out = mod.apply(extra, q, k, v, jax.random.PRNGKey(2), cfg)
    assert out.shape == (B, H, N, D)
    assert bool(jnp.all(jnp.isfinite(out)))


@pytest.mark.parametrize("name", configs.ATTENTION_KINDS)
def test_jit_and_grad(name):
    """Every module must jit and be differentiable w.r.t. q, k, v."""
    cfg = configs.model_for(name, num_features=16)
    mod = attention.get(name)
    q, k, v = _qkv(3, n=64)
    extra = mod.init(jax.random.PRNGKey(1), cfg, 64)

    @jax.jit
    def loss(q, k, v):
        out = mod.apply(extra, q, k, v, jax.random.PRNGKey(2), cfg)
        return jnp.sum(out**2)

    g = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    for gi in g:
        assert bool(jnp.all(jnp.isfinite(gi)))
    # v-grad must never be all-zero (information must flow)
    assert float(jnp.max(jnp.abs(g[2]))) > 0


def test_softmax_module_matches_reference_attention():
    cfg = configs.model_for("softmax")
    mod = attention.get("softmax")
    q, k, v = _qkv(5)
    out = mod.apply({}, q, k, v, jax.random.PRNGKey(0), cfg)
    want = jax.vmap(jax.vmap(ref.softmax_attention))(q, k, v)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)


def test_pallas_and_fused_paths_agree():
    """cfg.pallas flips the lowering, not the math."""
    for name in ("softmax", "kernelized", "skyformer"):
        q, k, v = _qkv(7)
        outs = []
        for pallas in (False, True):
            cfg = configs.model_for(name, pallas=pallas, num_features=48)
            mod = attention.get(name)
            out = mod.apply({}, q, k, v, jax.random.PRNGKey(9), cfg)
            outs.append(np.asarray(out))
        np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=2e-4, err_msg=name)


def test_skyformer_approximates_kernelized():
    """With a generous landmark budget Skyformer ~= Kernelized Attention."""
    q, k, v = _qkv(11)
    mod_ka = attention.get("kernelized")
    want = np.asarray(mod_ka.apply({}, q, k, v, jax.random.PRNGKey(0), configs.model_for("kernelized")))
    cfg = configs.model_for("skyformer", num_features=256, ns_iters=12)
    mod = attention.get("skyformer")
    got = np.asarray(mod.apply({}, q, k, v, jax.random.PRNGKey(1), cfg))
    rel = np.linalg.norm(got - want) / np.linalg.norm(want)
    assert rel < 0.15, rel

    # and the error shrinks with the budget (paper §4.5)
    cfg_small = configs.model_for("skyformer", num_features=8, ns_iters=12)
    small = np.asarray(mod.apply({}, q, k, v, jax.random.PRNGKey(1), cfg_small))
    rel_small = np.linalg.norm(small - want) / np.linalg.norm(want)
    assert rel < rel_small, (rel, rel_small)


def test_performer_approximates_softmax():
    q, k, v = _qkv(13)
    want = np.asarray(
        attention.get("softmax").apply({}, q, k, v, jax.random.PRNGKey(0), configs.model_for("softmax"))
    )
    cfg = configs.model_for("performer", num_features=512)
    got = np.asarray(attention.get("performer").apply({}, q, k, v, jax.random.PRNGKey(3), cfg))
    rel = np.linalg.norm(got - want) / np.linalg.norm(want)
    assert rel < 0.35, rel


def test_nystromformer_approximates_softmax():
    q, k, v = _qkv(17)
    want = np.asarray(
        attention.get("softmax").apply({}, q, k, v, jax.random.PRNGKey(0), configs.model_for("softmax"))
    )
    cfg = configs.model_for("nystromformer", num_features=64, ns_iters=10)
    got = np.asarray(attention.get("nystromformer").apply({}, q, k, v, jax.random.PRNGKey(3), cfg))
    rel = np.linalg.norm(got - want) / np.linalg.norm(want)
    assert rel < 0.5, rel


def test_linformer_params_created_and_used():
    cfg = configs.model_for("linformer", num_features=32)
    mod = attention.get("linformer")
    extra = mod.init(jax.random.PRNGKey(0), cfg, N)
    assert extra["proj_e"].shape == (32, N)
    q, k, v = _qkv(19)
    out1 = mod.apply(extra, q, k, v, jax.random.PRNGKey(1), cfg)
    extra2 = jax.tree_util.tree_map(lambda x: x * 2.0, extra)
    out2 = mod.apply(extra2, q, k, v, jax.random.PRNGKey(1), cfg)
    assert float(jnp.max(jnp.abs(out1 - out2))) > 1e-6  # params matter


def test_odd_sequence_lengths():
    """Non-power-of-two lengths exercise every module's padding path."""
    for name in configs.ATTENTION_KINDS:
        cfg = configs.model_for(name, num_features=16, block_size=16)
        mod = attention.get(name)
        q, k, v = _qkv(23, n=67)
        extra = mod.init(jax.random.PRNGKey(1), cfg, 67)
        out = mod.apply(extra, q, k, v, jax.random.PRNGKey(2), cfg)
        assert out.shape == (B, H, 67, D), name
        assert bool(jnp.all(jnp.isfinite(out))), name
