"""AOT lowering tests: artifacts are parseable HLO text with manifests that
agree with the actual lowered signatures.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from compile import aot, configs, model, train_step


@pytest.fixture(scope="module")
def tiny_entries(tmp_path_factory):
    """Lower a tiny config once for all tests in this module."""
    out = tmp_path_factory.mktemp("artifacts")
    # shrink the task so lowering is fast
    orig = configs.TASKS["listops"]
    configs.TASKS["listops"] = dataclasses.replace(orig, seq_len=64, batch_size=2)
    try:
        entries = aot.lower_config("listops", "skyformer", out, kinds=("init", "train", "eval", "embed"))
    finally:
        configs.TASKS["listops"] = orig
    return out, entries


def test_artifact_files_exist_and_are_hlo(tiny_entries):
    out, entries = tiny_entries
    assert len(entries) == 4
    for e in entries:
        text = (out / e["file"]).read_text()
        assert text.startswith("HloModule"), e["file"]
        assert "ENTRY" in text


def test_manifest_input_count_matches_hlo_params(tiny_entries):
    out, entries = tiny_entries
    for e in entries:
        text = (out / e["file"]).read_text()
        # count parameter() instructions inside the ENTRY computation only
        lines = text.splitlines()
        start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
        n_declared = 0
        for l in lines[start + 1 :]:
            if l.strip() == "}":
                break
            if " parameter(" in l:
                n_declared += 1
        assert n_declared == len(e["inputs"]), (e["name"], n_declared, len(e["inputs"]))


def test_train_signature_roundtrip(tiny_entries):
    _, entries = tiny_entries
    train = next(e for e in entries if e["kind"] == "train")
    n_p, n_o = train["num_params"], train["num_opt"]
    assert len(train["inputs"]) == n_p + n_o + 4  # tokens, labels, seed, lr
    assert len(train["outputs"]) == n_p + n_o + 2  # loss, acc
    # params leaves appear with identical specs in inputs and outputs
    for i in range(n_p + n_o):
        assert train["inputs"][i]["name"] == train["outputs"][i]["name"]
        assert train["inputs"][i]["shape"] == train["outputs"][i]["shape"]


def test_init_outputs_match_train_param_inputs(tiny_entries):
    _, entries = tiny_entries
    train = next(e for e in entries if e["kind"] == "train")
    init = next(e for e in entries if e["kind"] == "init")
    n_state = train["num_params"] + train["num_opt"]
    assert [o["name"] for o in init["outputs"]] == [
        i["name"] for i in train["inputs"][:n_state]
    ]


def test_leaf_names_unique(tiny_entries):
    _, entries = tiny_entries
    train = next(e for e in entries if e["kind"] == "train")
    names = [i["name"] for i in train["inputs"]]
    assert len(names) == len(set(names))


def test_dtype_vocabulary(tiny_entries):
    _, entries = tiny_entries
    for e in entries:
        for spec in e["inputs"] + e["outputs"]:
            assert spec["dtype"] in ("f32", "i32", "u32")


def test_smoke_manifest_consistent_if_present():
    """If `make artifacts` ran, validate the real manifest."""
    mpath = Path(__file__).resolve().parents[2] / "artifacts" / "manifest.json"
    if not mpath.exists():
        pytest.skip("artifacts not built")
    manifest = json.loads(mpath.read_text())
    for name, e in manifest["artifacts"].items():
        assert (mpath.parent / e["file"]).exists(), name
        assert e["task"] in configs.TASKS
        assert e["attention"] in configs.ATTENTION_KINDS
