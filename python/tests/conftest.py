"""Make `compile` importable whether pytest runs from repo root
(`pytest python/tests/`) or from `python/` (`pytest tests/`)."""

import sys
from pathlib import Path

_PYTHON_DIR = str(Path(__file__).resolve().parents[1])
if _PYTHON_DIR not in sys.path:
    sys.path.insert(0, _PYTHON_DIR)
