"""L2 model + train-step tests: shapes, dual tower, learning, determinism."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model, optimizer, train_step


def _tiny_task(name="listops", **over):
    base = configs.TASKS[name]
    return dataclasses.replace(base, seq_len=64, batch_size=4, **over)


def _batch(task, seed=0):
    key = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(key, model.token_shape(task), 0, task.vocab_size)
    labels = jax.random.randint(jax.random.split(key)[0], (task.batch_size,), 0, task.num_classes)
    return tokens, labels


@pytest.mark.parametrize("attn", configs.ATTENTION_KINDS)
def test_forward_shapes(attn):
    task = _tiny_task()
    cfg = configs.model_for(attn, num_features=16, block_size=16)
    params = model.init_params(jax.random.PRNGKey(0), task, cfg)
    tokens, _ = _batch(task)
    logits = model.forward(params, tokens, jax.random.PRNGKey(1), task, cfg)
    assert logits.shape == (task.batch_size, task.num_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_dual_tower_retrieval():
    task = _tiny_task("retrieval")
    assert task.dual
    cfg = configs.model_for("skyformer", num_features=16)
    params = model.init_params(jax.random.PRNGKey(0), task, cfg)
    tokens, _ = _batch(task)
    assert tokens.shape == (task.batch_size, 2, task.seq_len)
    logits = model.forward(params, tokens, jax.random.PRNGKey(1), task, cfg)
    assert logits.shape == (task.batch_size, task.num_classes)
    # swapping the two documents must change the interaction features' order
    swapped = model.forward(params, tokens[:, ::-1], jax.random.PRNGKey(1), task, cfg)
    assert float(jnp.max(jnp.abs(logits - swapped))) > 0


@pytest.mark.parametrize("attn", ["skyformer", "kernelized", "softmax"])
def test_train_step_reduces_loss(attn):
    """Overfit one tiny batch: loss must drop substantially in 30 steps."""
    task = _tiny_task()
    cfg = configs.model_for(attn, num_features=32)
    fns = train_step.make_fns(task, cfg)
    params, opt = fns["init"](jnp.uint32(0))
    tokens, labels = _batch(task, seed=1)
    step = jax.jit(fns["train"])
    first = None
    for i in range(30):
        params, opt, loss, acc = step(
            params, opt, tokens, labels, jnp.uint32(i), jnp.float32(3e-3)
        )
        if first is None:
            first = float(loss)
    assert float(loss) < 0.7 * first, (attn, first, float(loss))


def test_eval_step_matches_forward_loss():
    task = _tiny_task()
    cfg = configs.model_for("kernelized")
    fns = train_step.make_fns(task, cfg)
    params, _ = fns["init"](jnp.uint32(3))
    tokens, labels = _batch(task, seed=2)
    loss, acc = jax.jit(fns["eval"])(params, tokens, labels, jnp.uint32(5))
    assert 0.0 <= float(acc) <= 1.0
    assert float(loss) > 0


def test_embed_step_shapes():
    for name in ("listops", "retrieval"):
        task = _tiny_task(name)
        cfg = configs.model_for("skyformer", num_features=16)
        fns = train_step.make_fns(task, cfg)
        params, _ = fns["init"](jnp.uint32(0))
        tokens, _ = _batch(task)
        emb = jax.jit(fns["embed"])(params, tokens, jnp.uint32(0))
        want_dim = cfg.emb_dim * (2 if task.dual else 1)
        assert emb.shape == (task.batch_size, want_dim)


def test_init_deterministic_per_seed():
    task = _tiny_task()
    cfg = configs.model_for("softmax")
    fns = train_step.make_fns(task, cfg)
    p1, _ = fns["init"](jnp.uint32(7))
    p2, _ = fns["init"](jnp.uint32(7))
    p3, _ = fns["init"](jnp.uint32(8))
    l1 = jax.tree_util.tree_leaves(p1)
    l2 = jax.tree_util.tree_leaves(p2)
    l3 = jax.tree_util.tree_leaves(p3)
    for a, b in zip(l1, l2):
        np.testing.assert_array_equal(a, b)
    assert any(float(jnp.max(jnp.abs(a - c))) > 0 for a, c in zip(l1, l3))


def test_adam_matches_manual_update():
    """One Adam step against the closed-form update."""
    params = {"w": jnp.array([1.0, -2.0, 3.0])}
    grads = {"w": jnp.array([0.5, 0.5, -1.0])}
    state = optimizer.init(params)
    lr = jnp.float32(0.1)
    new, state2 = optimizer.update(grads, state, params, lr)
    # t=1: m_hat = g, v_hat = g^2  =>  p - lr * g/(|g| + eps) = p - lr*sign(g)
    want = params["w"] - 0.1 * jnp.sign(grads["w"])
    np.testing.assert_allclose(new["w"], want, rtol=1e-4)
    assert float(state2["t"]) == 1.0


def test_grads_reach_every_parameter():
    """No dead parameters: every leaf gets a nonzero gradient somewhere."""
    task = _tiny_task()
    cfg = configs.model_for("skyformer", num_features=32)
    params = model.init_params(jax.random.PRNGKey(0), task, cfg)
    tokens, labels = _batch(task, seed=4)

    def loss_fn(p):
        logits = model.forward(p, tokens, jax.random.PRNGKey(1), task, cfg)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))

    grads = jax.grad(loss_fn)(params)
    flat = jax.tree_util.tree_flatten_with_path(grads)[0]
    # embedding rows for unseen tokens are legitimately zero; check per-leaf max
    for path, g in flat:
        name = jax.tree_util.keystr(path)
        assert bool(jnp.all(jnp.isfinite(g))), name
        if "embed" in name or "pos" in name:
            continue
        assert float(jnp.max(jnp.abs(g))) > 0, f"dead parameter {name}"
