"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/dtypes (the session guide's required pattern); each
property asserts allclose against kernels.ref.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gaussian, newton_schulz, nystrom, ref, softmax

SETTINGS = dict(deadline=None, max_examples=12)


def _qkv(seed: int, n: int, m: int, p: int, d_v: int, dtype, scale=0.6):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = (jax.random.normal(kq, (n, p), jnp.float32) * scale).astype(dtype)
    k = (jax.random.normal(kk, (m, p), jnp.float32) * scale).astype(dtype)
    v = (jax.random.normal(kv, (m, d_v), jnp.float32)).astype(dtype)
    return q, k, v


shape_strategy = st.tuples(
    st.integers(1, 300),  # n
    st.integers(1, 300),  # m
    st.sampled_from([4, 16, 32, 64]),  # p
    st.sampled_from([8, 32, 64]),  # d_v
    st.integers(0, 2**31 - 1),  # seed
)


@given(shape_strategy, st.sampled_from([jnp.float32, jnp.bfloat16]))
@settings(**SETTINGS)
def test_kernelized_attention_matches_ref(dims, dtype):
    n, m, p, d_v, seed = dims
    q, k, v = _qkv(seed, n, m, p, d_v, dtype)
    got = gaussian.kernelized_attention(q, k, v, block_q=64, block_k=64)
    want = ref.kernelized_attention(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * 10)


@given(shape_strategy, st.sampled_from([jnp.float32, jnp.bfloat16]))
@settings(**SETTINGS)
def test_softmax_attention_matches_ref(dims, dtype):
    n, m, p, d_v, seed = dims
    q, k, v = _qkv(seed, n, m, p, d_v, dtype)
    got = softmax.softmax_attention(q, k, v, block_q=64, block_k=64)
    want = ref.softmax_attention(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * 10)


@given(shape_strategy)
@settings(**SETTINGS)
def test_gaussian_scores_matches_ref(dims):
    n, m, p, _, seed = dims
    q, k, _ = _qkv(seed, n, m, p, 8, jnp.float32)
    got = gaussian.gaussian_scores(q, k, block_q=64)
    want = ref.gaussian_scores(q, k)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@given(
    st.integers(2, 96),  # d (landmarks)
    st.sampled_from([4, 16, 32]),
    st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_ns_inverse_matches_exact(d, p, seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (d, p), jnp.float32) * 0.5
    m = ref.gaussian_scores(x, x)  # PSD
    # low-dim Gaussian grams reach cond ~1e5 with gamma=1e-3; NS needs ~30
    # iterations to hit the f32 floor (~3e-4 relative) there.
    got = newton_schulz.ns_inverse(m, gamma=1e-3, iters=30)
    want = np.linalg.inv(np.asarray(m) + 1e-3 * np.eye(d, dtype=np.float32))
    scale = np.max(np.abs(want))
    np.testing.assert_allclose(got / scale, want / scale, atol=2e-3)


@given(shape_strategy, st.integers(4, 64))
@settings(**SETTINGS)
def test_skyformer_matches_ref(dims, n_landmarks):
    n, m, p, d_v, seed = dims
    q, k, v = _qkv(seed, n, m, p, d_v, jnp.float32)
    d = min(n_landmarks, n + m)
    lmk = ref.uniform_landmarks(jax.random.PRNGKey(seed ^ 0x5EED), n + m, d)
    got = nystrom.skyformer_attention(q, k, v, lmk, iters=8, block_q=64, block_k=64)
    want = ref.skyformer_attention(q, k, v, lmk, iters=8)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_kernelized_attention_identity_case():
    """kappa(x, x) has unit diagonal: KA of a single token returns v."""
    q = jnp.ones((1, 8)) * 0.3
    v = jnp.arange(8, dtype=jnp.float32)[None, :]
    out = gaussian.kernelized_attention(q, q, v)
    np.testing.assert_allclose(out, v, rtol=1e-6)


def test_gaussian_scores_range():
    """Gaussian kernel values always lie in (0, 1]."""
    q, k, _ = _qkv(7, 100, 90, 16, 8, jnp.float32, scale=2.0)
    s = np.asarray(gaussian.gaussian_scores(q, k))
    assert s.max() <= 1.0 + 1e-6
    # mathematically > 0; far pairs underflow to +0.0 in f32
    assert s.min() >= 0.0
    assert (s > 0).any()


def test_softmax_rows_sum_to_one_via_ones_value():
    """softmax attention with V = 1 returns exactly 1 (row-stochastic)."""
    q, k, _ = _qkv(3, 130, 70, 16, 4, jnp.float32)
    v = jnp.ones((70, 4), jnp.float32)
    out = softmax.softmax_attention(q, k, v)
    np.testing.assert_allclose(out, np.ones((130, 4)), rtol=1e-5)


def test_landmark_gram_is_symmetric_psd():
    q, k, _ = _qkv(11, 80, 80, 16, 8, jnp.float32)
    lmk = ref.uniform_landmarks(jax.random.PRNGKey(0), 160, 32)
    m = np.asarray(nystrom.landmark_gram(q, k, lmk))
    np.testing.assert_allclose(m, m.T, atol=1e-6)
    w = np.linalg.eigvalsh(m)
    assert w.min() > -1e-4


@pytest.mark.parametrize("block_q,block_k", [(8, 8), (32, 128), (128, 32), (256, 256)])
def test_block_shape_invariance(block_q, block_k):
    """Output must not depend on the BlockSpec tiling."""
    q, k, v = _qkv(5, 200, 170, 32, 32, jnp.float32)
    base = ref.kernelized_attention(q, k, v)
    got = gaussian.kernelized_attention(q, k, v, block_q=block_q, block_k=block_k)
    np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-5)
