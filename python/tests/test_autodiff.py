"""custom_vjp wrappers: Pallas forward must pair with a backward that matches
the reference gradients (the wrappers exist because pallas_call has no
transpose rule — see kernels/autodiff.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import autodiff, ref


def _qkv(seed=0, n=48, m=40, p=16, d_v=8):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (n, p)) * 0.4
    k = jax.random.normal(kk, (m, p)) * 0.4
    v = jax.random.normal(kv, (m, d_v))
    return q, k, v


def _check_grads(wrapped, reference, args, tol=1e-4):
    def loss_w(*a):
        return jnp.sum(wrapped(*a) ** 2)

    def loss_r(*a):
        return jnp.sum(reference(*a) ** 2)

    gw = jax.grad(loss_w, argnums=tuple(range(len(args))))(*args)
    gr = jax.grad(loss_r, argnums=tuple(range(len(args))))(*args)
    for a, b in zip(gw, gr):
        np.testing.assert_allclose(a, b, rtol=tol, atol=tol)


def test_kernelized_grads_match_ref():
    q, k, v = _qkv(1)
    _check_grads(autodiff.kernelized_attention, ref.kernelized_attention, (q, k, v))


def test_softmax_grads_match_ref():
    q, k, v = _qkv(2)
    _check_grads(autodiff.softmax_attention, ref.softmax_attention, (q, k, v))


def test_skyformer_grads_match_ref():
    q, k, v = _qkv(3)
    lmk = ref.uniform_landmarks(jax.random.PRNGKey(0), q.shape[0] + k.shape[0], 24)

    def wrapped(q, k, v):
        return autodiff.skyformer_attention(q, k, v, lmk, 1e-3, 8)

    def reference(q, k, v):
        return ref.skyformer_attention(q, k, v, lmk, gamma=1e-3, iters=8)

    _check_grads(wrapped, reference, (q, k, v), tol=5e-4)


def test_finite_difference_directional():
    """Forward-mode sanity: directional derivative vs finite differences."""
    q, k, v = _qkv(4, n=24, m=20, p=8, d_v=4)
    key = jax.random.PRNGKey(9)
    dq = jax.random.normal(key, q.shape) * 1.0

    def f(q_):
        return jnp.sum(autodiff.kernelized_attention(q_, k, v) ** 2)

    g = jax.grad(f)(q)
    analytic = float(jnp.sum(g * dq))
    eps = 1e-3
    numeric = (float(f(q + eps * dq)) - float(f(q - eps * dq))) / (2 * eps)
    assert abs(analytic - numeric) < 3e-2 * max(1.0, abs(analytic)), (analytic, numeric)


def test_vjp_under_vmap():
    """The wrappers must survive vmap (how attention modules call them)."""
    b = 3
    qs = jnp.stack([_qkv(i)[0] for i in range(b)])
    ks = jnp.stack([_qkv(i)[1] for i in range(b)])
    vs = jnp.stack([_qkv(i)[2] for i in range(b)])

    def loss(q, k, v):
        return jnp.sum(jax.vmap(autodiff.kernelized_attention)(q, k, v) ** 2)

    g = jax.grad(loss, argnums=(0, 1, 2))(qs, ks, vs)
    want = jax.grad(
        lambda q, k, v: jnp.sum(jax.vmap(ref.kernelized_attention)(q, k, v) ** 2),
        argnums=(0, 1, 2),
    )(qs, ks, vs)
    for a, b_ in zip(g, want):
        np.testing.assert_allclose(a, b_, rtol=1e-4, atol=1e-4)
